"""Batched multi-RHS solve sweep: B in {1, 2, 4, 8, 16}, batched pipeline
vs B sequential solves, for both solvers on the paper's fully-unbounded
workload -- the amortization a vortex-method driver (several RHS per
timestep over one plan) gets for free from the batch axis.

``PoissonSolver`` runs in-process; ``DistributedPoissonSolver`` runs on an
8-device host-platform (2 x 4) pencil mesh in a subprocess (same pattern
as bench_comm).  The headline number -- the acceptance bar of the batched
execution PR -- is the distributed B=8 speedup: one batched solve vs 8
sequential solves on the host mesh.  Plus one Biot-Savart row: the
uniform-plan batched 3-component pipeline vs the sequential per-component
implementation.

Full sweep lands in ``BENCH_batch.json`` (quick mode:
``BENCH_batch.quick.json``), rendered in EXPERIMENTS.md section
"Batched multi-RHS execution".
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys, time
import numpy as np
import jax, jax.numpy as jnp
from repro.core.bc import BCType, DataLayout
from repro.core.comm import CommConfig
from repro.core.solver import PoissonSolver
from repro.distributed.pencil import DistributedPoissonSolver
from repro.core.biot_savart import BiotSavartSolver

cfg = json.loads(sys.argv[1])
n, reps, bs = cfg["n"], cfg["reps"], cfg["bs"]
U = (BCType.UNB, BCType.UNB)
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
fb = rng.standard_normal((max(bs), n, n, n)).astype(np.float32)
rows = []


def best(fn, reps):
    fn()                                  # warm (compile both paths first)
    t = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        t = min(t, time.perf_counter() - t0)
    return t


def sweep(name, solver):
    for b in bs:
        f1 = jnp.asarray(fb[:b])
        t_loop = best(lambda: [solver.solve(f1[i]).block_until_ready()
                               for i in range(b)], reps)
        t_batch = best(lambda: solver.solve(f1).block_until_ready(), reps)
        rows.append({"solver": name, "B": b,
                     "loop_ms": t_loop * 1e3, "batch_ms": t_batch * 1e3,
                     "speedup": t_loop / t_batch})

sweep("poisson", PoissonSolver((n, n, n), 1.0, (U, U, U),
                               layout=DataLayout.CELL))
sweep("pencil", DistributedPoissonSolver(
    (n, n, n), 1.0, (U, U, U), mesh=mesh,
    comm=CommConfig("overlap", 2)))

# Biot-Savart: the component axis IS the batch -- batched uniform-plan
# pipeline vs the sequential 3-solve implementation
bsolver = BiotSavartSolver((n, n, n), 1.0, [[U, U, U]] * 3,
                           layout=DataLayout.CELL)
assert bsolver.batched
fv = jnp.asarray(fb[:3])
seq = jax.jit(bsolver._solve_impl)
t_seq = best(lambda: seq(fv).block_until_ready(), reps)
t_bat = best(lambda: bsolver._solve(fv).block_until_ready(), reps)
rows.append({"solver": "biot_savart", "B": 3,
             "loop_ms": t_seq * 1e3, "batch_ms": t_bat * 1e3,
             "speedup": t_seq / t_bat})
print("BENCH_JSON " + json.dumps(rows))
"""


def _sweep(n, reps, bs):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT,
         json.dumps({"n": n, "reps": reps, "bs": bs})],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][-1]
    return json.loads(line[len("BENCH_JSON "):])


def run(quick=True):
    n = 32
    bs = [1, 2, 4, 8] if quick else [1, 2, 4, 8, 16]
    try:
        rows = _sweep(n, 3 if quick else 5, bs)
    except RuntimeError as e:
        return [("batch_error", 0.0, str(e)[-200:])]
    headline = next(r for r in rows
                    if r["solver"] == "pencil" and r["B"] == 8)
    payload = {"mode": "quick" if quick else "full", "grid": n,
               "mesh": [2, 4], "bcs": "unb", "comm": "overlap:2",
               "rows": rows,
               "headline": {"solver": "pencil", "B": 8,
                            "speedup_vs_sequential": headline["speedup"]}}
    fname = "BENCH_batch.quick.json" if quick else "BENCH_batch.json"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, fname), "w") as fh:
        json.dump(payload, fh, indent=2)
    return [(f"batch_{r['solver']}_B{r['B']}", r["batch_ms"] * 1e3,
             f"{r['speedup']:.2f}x_vs_loop") for r in rows]


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit
    out_rows = run(quick="--full" not in sys.argv)
    emit(out_rows)
    # standalone/CI runs must FAIL loudly when the sweep crashed (run()
    # returns an error row for the benchmark-harness aggregation instead
    # of raising); otherwise the acceptance headline silently vanishes
    if any(name == "batch_error" for name, _, _ in out_rows):
        sys.exit(1)
