"""Paper Figures 9/10/18/19: Biot-Savart convergence (spectral + FD)."""
from __future__ import annotations

import sys
import time

import numpy as np


def run(quick=True):
    sys.path.insert(0, "tests")
    from test_biot_savart import linf
    from repro.core.green import GreenKind

    ns = (16, 32) if quick else (32, 64)
    rows = []
    for fig, g, fd in (("fig9", GreenKind.CHAT2, 0),
                       ("fig9", GreenKind.HEJ4, 0),
                       ("fig10", GreenKind.HEJ2, 6),
                       ("fig18", GreenKind.HEJ4, 2),
                       ("fig19", GreenKind.HEJ4, 4)):
        t0 = time.perf_counter()
        errs = [linf(n, g, fd) for n in ns]
        us = (time.perf_counter() - t0) / len(ns) * 1e6
        order = float(np.log(errs[0] / errs[-1]) / np.log(ns[-1] / ns[0]))
        rows.append((f"{fig}_biot_{g}_fd{fd}", us,
                     f"order={order:.2f};err={errs[-1]:.2e}"))
    return rows


if __name__ == "__main__":
    from common import emit
    emit(run())
