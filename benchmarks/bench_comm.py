"""Paper Table II / Fig 11: comm-strategy comparison (a2a / pipelined /
fused) on an 8-device pencil grid -- the accFFT-comparison analogue: the
same forward+backward FFT workload under each strategy.

Runs in a subprocess with 8 host devices so the main process keeps 1.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver

n = int(os.environ.get("BENCH_N", "64"))
P = (BCType.PER, BCType.PER)
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
rows = []
for strategy in ("a2a", "pipelined", "fused"):
    s = DistributedPoissonSolver((n, n, n), 1.0, (P, P, P), mesh=mesh,
                                 comm=CommConfig(strategy=strategy,
                                                 n_chunks=2))
    f = rng.standard_normal((n, n, n)).astype(np.float32)
    u = s.solve(f); u.block_until_ready()
    t0 = time.time()
    reps = 5
    for _ in range(reps):
        u = s.solve(f); u.block_until_ready()
    dt = (time.time() - t0) / reps
    thr = f.nbytes / dt / 8 / 1e6   # MB/s per rank
    rows.append({"strategy": strategy, "us": dt * 1e6,
                 "mbps_rank": thr})
print(json.dumps(rows))
"""


def run(quick=True):
    env = dict(os.environ, PYTHONPATH="src", BENCH_N="48" if quick else "96")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env)
    if out.returncode != 0:
        return [("tab2_comm_error", 0.0, out.stderr[-200:])]
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    return [(f"tab2_comm_{r['strategy']}", r["us"],
             f"{r['mbps_rank']:.1f}MB/s/rank") for r in rows]


if __name__ == "__main__":
    from common import emit
    emit(run())
