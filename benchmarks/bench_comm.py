"""Paper Table II / Fig 11: comm-strategy comparison on an 8-device pencil
grid -- the accFFT-comparison analogue: the same forward+backward FFT
workload under every (strategy, n_chunks) pair, plus the ``comm="auto"``
autotuner pick.  The full sweep lands in ``BENCH_comm.json`` (the table
rendered in EXPERIMENTS.md §Comm strategies).

Runs in a subprocess with 8 host devices so the main process keeps 1.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SWEEP = [("a2a", 1), ("fused", 1),
         ("pipelined", 2), ("pipelined", 4), ("pipelined", 8),
         ("overlap", 2), ("overlap", 4), ("overlap", 8)]

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys, time
import numpy as np
import jax, jax.numpy as jnp
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver

n = int(os.environ.get("BENCH_N", "64"))
reps = int(os.environ.get("BENCH_REPS", "5"))
sweep = json.loads(sys.argv[1])
P = (BCType.PER, BCType.PER)
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
f = rng.standard_normal((n, n, n)).astype(np.float32)
rows = []

def timed(comm):
    s = DistributedPoissonSolver((n, n, n), 1.0, (P, P, P), mesh=mesh,
                                 comm=comm)
    u = s.solve(f); u.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        u = s.solve(f); u.block_until_ready()
    return s, (time.perf_counter() - t0) / reps

for strategy, nc in sweep:
    s, dt = timed(CommConfig(strategy=strategy, n_chunks=nc))
    rows.append({"strategy": strategy, "n_chunks": nc, "us": dt * 1e6,
                 "mbps_rank": f.nbytes / dt / 8 / 1e6})

s, dt = timed("auto")
rows.append({"strategy": "auto", "n_chunks": s.comm.n_chunks,
             "picked": f"{s.comm.strategy}:{s.comm.n_chunks}",
             "us": dt * 1e6, "mbps_rank": f.nbytes / dt / 8 / 1e6,
             "sweep_us": {k: v * 1e6 for k, v in
                          getattr(s, "autotune_results", {}).items()}})
print("BENCH_JSON " + json.dumps(rows))
"""


def _sweep(n, reps, sweep):
    env = dict(os.environ, PYTHONPATH="src", BENCH_N=str(n),
               BENCH_REPS=str(reps))
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_COMM_CACHE", None)  # the sweep must run live
    out = subprocess.run([sys.executable, "-c", _SCRIPT, json.dumps(sweep)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][-1]
    return json.loads(line[len("BENCH_JSON "):])


def run(quick=True):
    n = 48 if quick else 96
    sweep = SWEEP[:6] if quick else SWEEP
    try:
        rows = _sweep(n, 3 if quick else 5, sweep)
    except RuntimeError as e:
        return [("tab2_comm_error", 0.0, str(e)[-200:])]
    payload = {"mode": "quick" if quick else "full", "grid": n,
               "mesh": [2, 4], "bcs": "per", "rows": rows}
    fname = "BENCH_comm.quick.json" if quick else "BENCH_comm.json"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, fname), "w") as fh:
        json.dump(payload, fh, indent=2)
    return [(f"tab2_comm_{r['strategy']}_c{r['n_chunks']}", r["us"],
             f"{r['mbps_rank']:.1f}MB/s/rank" +
             (f";picked={r['picked']}" if "picked" in r else ""))
            for r in rows]


if __name__ == "__main__":
    from common import emit
    emit(run(quick="--full" not in sys.argv))
