"""Paper Table II / Fig 11: comm-strategy comparison on an 8-device pencil
grid -- the accFFT-comparison analogue: the same forward+backward FFT
workload under every (strategy, n_chunks) pair, plus the ``comm="auto"``
autotuner pick.  The full sweep lands in ``BENCH_comm.json`` (the table
rendered in EXPERIMENTS.md §Comm strategies).

Runs in a subprocess with 8 host devices so the main process keeps 1.

``--search`` (DESIGN.md #12) runs the guided-vs-brute A/B instead: the
exhaustive comm sweep and the cost-model shortlist are timed over one
memoized timer, the two winners are re-timed head-to-head, and the
``search`` section of ``BENCH_comm.json`` records the account.  With
``--check`` it gates (CI perf-guard): the guided winner must stay within
10% of the brute winner while wall-clock timing >= 5x fewer candidates.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SWEEP = [("a2a", 1), ("fused", 1),
         ("pipelined", 2), ("pipelined", 4), ("pipelined", 8),
         ("overlap", 2), ("overlap", 4), ("overlap", 8)]

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys, time
import numpy as np
import jax, jax.numpy as jnp
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver

n = int(os.environ.get("BENCH_N", "64"))
reps = int(os.environ.get("BENCH_REPS", "5"))
sweep = json.loads(sys.argv[1])
P = (BCType.PER, BCType.PER)
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
f = rng.standard_normal((n, n, n)).astype(np.float32)
rows = []

def timed(comm):
    s = DistributedPoissonSolver((n, n, n), 1.0, (P, P, P), mesh=mesh,
                                 comm=comm)
    u = s.solve(f); u.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        u = s.solve(f); u.block_until_ready()
    return s, (time.perf_counter() - t0) / reps

for strategy, nc in sweep:
    s, dt = timed(CommConfig(strategy=strategy, n_chunks=nc))
    rows.append({"strategy": strategy, "n_chunks": nc, "us": dt * 1e6,
                 "mbps_rank": f.nbytes / dt / 8 / 1e6})

s, dt = timed("auto")
rows.append({"strategy": "auto", "n_chunks": s.comm.n_chunks,
             "picked": f"{s.comm.strategy}:{s.comm.n_chunks}",
             "us": dt * 1e6, "mbps_rank": f.nbytes / dt / 8 / 1e6,
             "sweep_us": {k: v * 1e6 for k, v in
                          getattr(s, "autotune_results", {}).items()}})
print("BENCH_JSON " + json.dumps(rows))
"""


def _sweep(n, reps, sweep):
    env = dict(os.environ, PYTHONPATH="src", BENCH_N=str(n),
               BENCH_REPS=str(reps))
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_COMM_CACHE", None)  # the sweep must run live
    out = subprocess.run([sys.executable, "-c", _SCRIPT, json.dumps(sweep)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][-1]
    return json.loads(line[len("BENCH_JSON "):])


def run(quick=True):
    n = 48 if quick else 96
    sweep = SWEEP[:6] if quick else SWEEP
    try:
        rows = _sweep(n, 3 if quick else 5, sweep)
    except RuntimeError as e:
        return [("tab2_comm_error", 0.0, str(e)[-200:])]
    payload = {"mode": "quick" if quick else "full", "grid": n,
               "mesh": [2, 4], "bcs": "per", "rows": rows}
    fname = "BENCH_comm.quick.json" if quick else "BENCH_comm.json"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, fname), "w") as fh:
        json.dump(payload, fh, indent=2)
    return [(f"tab2_comm_{r['strategy']}_c{r['n_chunks']}", r["us"],
             f"{r['mbps_rank']:.1f}MB/s/rank" +
             (f";picked={r['picked']}" if "picked" in r else ""))
            for r in rows]


_SEARCH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys, time
import jax, jax.numpy as jnp
from repro.core.bc import BCType, DataLayout
from repro.core.comm import autotune_candidates, cfg_label
from repro.distributed.pencil import DistributedPoissonSolver
from repro.plan.search import guided_comm_candidates

n = int(os.environ.get("BENCH_N", "48"))
reps = int(os.environ.get("BENCH_REPS", "3"))
P = (BCType.PER, BCType.PER)
p1, p2 = 2, 4
mesh = jax.make_mesh((p1, p2), ("data", "model"))
ds = DistributedPoissonSolver((n, n, n), 1.0, (P, P, P),
                              layout=DataLayout.CELL, mesh=mesh,
                              dtype=jnp.float32)
time_cfg = ds.comm_time_fn(reps=reps)
brute = autotune_candidates(4, folds=("pack", "unpack"))
census = {}
guided = guided_comm_candidates(ds.plan, p1, p2, ds.dtype,
                                folds=("pack", "unpack"),
                                relayout=ds.relayout, census=census)
memo = {}
def timed(cfg):
    lbl = cfg_label(cfg)
    if lbl not in memo:
        memo[lbl] = time_cfg(cfg)
    return memo[lbl]
bt = {cfg_label(c): timed(c) for c in brute}
gt = {cfg_label(c): timed(c) for c in guided}
bw, gw = min(bt, key=bt.get), min(gt, key=gt.get)
if bw == gw:
    ratio = 1.0
else:
    # interleaved head-to-head re-timing of the two winners only
    by = {cfg_label(c): c for c in brute}
    tb = tg = float("inf")
    for _ in range(5):
        tb = min(tb, time_cfg(by[bw]))
        tg = min(tg, time_cfg(by[gw]))
    ratio = tg / tb
out = {"grid": n, "mesh": [p1, p2], "bcs": "per",
       "space": census["space"],
       "timed_brute": len(bt), "timed_guided": len(gt),
       "pruned_padding": census["pruned_padding"],
       "shortlist": census["shortlist"],
       "predicted_us": {k: v * 1e6 for k, v in census["predicted"].items()},
       "brute_us": {k: v * 1e6 for k, v in bt.items()},
       "guided_us": {k: v * 1e6 for k, v in gt.items()},
       "brute_winner": bw, "guided_winner": gw, "ratio": ratio}
print("BENCH_JSON " + json.dumps(out))
"""


def run_search(n=48, reps=3, check=False):
    """Guided-vs-brute A/B; merged into BENCH_comm.json under "search"."""
    env = dict(os.environ, PYTHONPATH="src", BENCH_N=str(n),
               BENCH_REPS=str(reps))
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_COMM_CACHE", None)  # both sweeps must run live
    out = subprocess.run([sys.executable, "-c", _SEARCH_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][-1]
    res = json.loads(line[len("BENCH_JSON "):])
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_comm.json")
    try:
        with open(path) as fh:
            payload = json.load(fh)
    except (OSError, ValueError):
        payload = {}
    payload["search"] = res
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"[search] n={res['grid']}^3 mesh={tuple(res['mesh'])}: "
          f"brute={res['brute_winner']} "
          f"({res['brute_us'][res['brute_winner']]:.0f}us, "
          f"{res['timed_brute']} timed) vs "
          f"guided={res['guided_winner']} "
          f"({res['guided_us'][res['guided_winner']]:.0f}us, "
          f"{res['timed_guided']} timed), ratio={res['ratio']:.3f}")
    if check:
        assert res["ratio"] <= 1.10, (
            f"guided winner {res['guided_winner']} is {res['ratio']:.2f}x "
            f"the brute winner {res['brute_winner']} (> 1.10)")
        assert res["timed_brute"] >= 5 * res["timed_guided"], (
            f"guided timed {res['timed_guided']} of {res['timed_brute']} "
            "-- less than the gated 5x reduction")
        print("[search] gates passed: ratio <= 1.10, >= 5x fewer timed")
    return res


if __name__ == "__main__":
    if "--search" in sys.argv:
        run_search(n=96 if "--full" in sys.argv else 48,
                   check="--check" in sys.argv)
    else:
        from common import emit
        emit(run(quick="--full" not in sys.argv))
