"""Paper Figures 6/7/8: convergence of the three validation cases."""
from __future__ import annotations

import sys
import time

import numpy as np


def run(quick=True):
    sys.path.insert(0, "tests")
    from test_poisson import CASES, linf_error
    from repro.core.bc import DataLayout
    from repro.core.green import GreenKind

    rows = []
    plan = {
        "A": [GreenKind.CHAT2, GreenKind.LGF2, GreenKind.HEJ2],
        "B": [GreenKind.CHAT2, GreenKind.LGF2, GreenKind.HEJ2,
              GreenKind.HEJ4, GreenKind.HEJ6, GreenKind.HEJ0],
        "C": [GreenKind.CHAT2, GreenKind.HEJ2, GreenKind.HEJ4],
    }
    ns = (16, 32) if quick else (32, 64)
    for case, greens in plan.items():
        _, bcs = CASES[case]
        for g in greens:
            errs, t0 = [], time.perf_counter()
            for n in ns:
                errs.append(linf_error(case, bcs, n, DataLayout.NODE, g))
            us = (time.perf_counter() - t0) / len(ns) * 1e6
            order = float(np.log(errs[0] / errs[-1]) /
                          np.log(ns[-1] / ns[0]))
            rows.append((f"fig{ {'A':6,'B':7,'C':8}[case] }_conv_{case}_{g}",
                         us, f"order={order:.2f};err{ns[-1]}={errs[-1]:.2e}"))
    return rows


if __name__ == "__main__":
    from common import emit
    emit(run())
