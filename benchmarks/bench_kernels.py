"""Kernel + transform-path benchmarks.

Two jobs:
  1. Pallas kernels (interpret mode) vs jnp reference timings + allclose
     (the historical CSV rows, still consumed by benchmarks/run.py);
  2. the r2r transform hot path: NEW half-spectrum rfft transforms
     (repro.core.transforms) vs the SEED full-complex-FFT path
     (repro.core.transforms_ref), jit-compiled, on an N=256^3-equivalent
     batch -- written to ``BENCH_kernels.json`` so the perf trajectory of
     the transform engine is recorded per PR.

Estimated HBM bytes per transform (per batch row of length M, f32):
  old: read M real + write/read 2M complex ext + complex FFT out 2M complex
       + twiddle read M complex + write M real
  new: read M real + write/read 2M real ext + rfft out (M+1) complex
       + twiddle read (M+1) complex + write M real
i.e. the extension and FFT traffic halves.
"""
from __future__ import annotations

import json
import os

import numpy as np
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.fft_stockham import (fft_stockham, fft_stockham_twiddle,
                                        stage_count)


def _bytes_est(m: int, rows: int, path: str) -> int:
    if path == "old":
        per_row = m * 4 + 2 * (2 * m * 8) + 2 * m * 8 + m * 8 + m * 4
    else:
        per_row = m * 4 + 2 * (2 * m * 4) + (m + 1) * 8 + (m + 1) * 8 + m * 4
    return per_row * rows


def bench_r2r_paths(quick=True):
    """Old full-complex vs new half-spectrum r2r transforms, jitted."""
    import jax
    from common import time_fn
    from repro.core.bc import TransformKind
    from repro.core import transforms as tr
    from repro.core import transforms_ref as trf

    # N=256^3 batch: transforms act on the last axis of a (256^2, 256) view
    m = 256
    rows = 64 * 64 if quick else 256 * 256
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((rows, m)), jnp.float32)

    kinds = {
        "dct1": TransformKind.DCT1, "dct2": TransformKind.DCT2,
        "dct3": TransformKind.DCT3, "dct4": TransformKind.DCT4,
        "dst1": TransformKind.DST1, "dst2": TransformKind.DST2,
        "dst3": TransformKind.DST3, "dst4": TransformKind.DST4,
    }
    from common import interleaved_min
    per_kind = {}
    for name, kind in kinds.items():
        new_fn = jax.jit(lambda v, k=kind: tr.r2r_forward(v, k))
        old_fn = jax.jit(lambda v, k=kind: trf.r2r_forward(v, k))
        err = float(jnp.max(jnp.abs(new_fn(x) - old_fn(x))))  # + warmup
        best = interleaved_min({"new": lambda: new_fn(x),
                                "old": lambda: old_fn(x)}, reps=7)
        per_kind[name] = {
            "old_us": best["old"] * 1e6, "new_us": best["new"] * 1e6,
            "speedup": best["old"] / best["new"], "maxerr_vs_old": err,
        }
    speedups = [v["speedup"] for v in per_kind.values()]
    return {
        "shape": [rows, m],
        "dtype": "float32",
        "per_kind": per_kind,
        "geomean_speedup": float(np.exp(np.mean(np.log(speedups)))),
        "old_bytes_est": _bytes_est(m, rows, "old"),
        "new_bytes_est": _bytes_est(m, rows, "new"),
    }


def run(quick=True):
    import jax
    from common import time_fn
    rows = []
    rng = np.random.default_rng(0)
    n = 512 if quick else 2048
    b = 64

    # NOTE: every kern_* row below executes the Pallas kernel in INTERPRET
    # mode (CPU emulation; no TPU in this environment).  Those timings are
    # tagged interpret=True in the CSV and the JSON and are excluded from
    # all speedup claims -- an interpreted kernel measured against a real
    # jitted reference is not a benchmark, it is a correctness probe with a
    # wall clock attached.
    re = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    im = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    t_kernel = time_fn(fft_stockham, re, im)
    t_ref = time_fn(lambda a, c: ref.fft_ref(a, c), re, im)
    gr, gi = fft_stockham(re, im)
    wr, wi = ref.fft_ref(re, im)
    err = float(jnp.max(jnp.abs(gr - wr)))
    rows.append(("kern_fft_stockham", t_kernel * 1e6,
                 f"ref_us={t_ref*1e6:.0f};maxerr={err:.1e}", True))

    # radix-4 vs radix-2 stage pipelines (same kernel, max_radix knob):
    # the butterfly pass count halves on pow2 lengths; interpret-mode
    # timings recorded for trajectory only
    r4 = {}
    for nn in (256, 1024, 4096):
        rr = jnp.asarray(rng.standard_normal((b, nn)), jnp.float32)
        ii = jnp.asarray(rng.standard_normal((b, nn)), jnp.float32)
        t2 = time_fn(lambda a, c: fft_stockham(a, c, max_radix=2), rr, ii)
        t4 = time_fn(lambda a, c: fft_stockham(a, c, max_radix=4), rr, ii)
        g2 = fft_stockham(rr, ii, max_radix=2)
        g4 = fft_stockham(rr, ii, max_radix=4)
        err = float(max(jnp.max(jnp.abs(g2[0] - g4[0])),
                        jnp.max(jnp.abs(g2[1] - g4[1]))))
        r4[str(nn)] = {
            "radix2_us": t2 * 1e6, "radix4_us": t4 * 1e6,
            "stages_radix2": stage_count(nn, 2),
            "stages_radix4": stage_count(nn, 4),
            "maxerr_r4_vs_r2": err, "interpret": True,
        }
        rows.append((f"kern_fft_radix4_n{nn}", t4 * 1e6,
                     f"radix2_us={t2*1e6:.0f};"
                     f"stages={stage_count(nn, 4)}v{stage_count(nn, 2)};"
                     f"maxerr={err:.1e}", True))

    # fused FFT epilogue (post-twiddle in the final stage's registers):
    # one kernel where the unfused path ran fft_stockham + twiddle_pack
    a_tw = jnp.asarray(rng.standard_normal(n // 2 + 1), jnp.float32)
    b_tw = jnp.asarray(rng.standard_normal(n // 2 + 1), jnp.float32)
    t_fused = time_fn(lambda a, c: fft_stockham_twiddle(a, c, a_tw, b_tw),
                      re, im)
    rows.append(("kern_fft_twiddle_epilogue", t_fused * 1e6,
                 "fused fft+twiddle_pack;one HBM round trip", True))

    g = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    f = (re + 1j * im).astype(jnp.complex64)
    t_kernel = time_fn(ops.green_multiply, f, g, 0.5)
    t_ref = time_fn(lambda a, c: a * c * 0.5, f, g)
    rows.append(("kern_spectral_scale", t_kernel * 1e6,
                 f"ref_us={t_ref*1e6:.0f}", True))

    t_kernel = time_fn(ops.dct2_post_twiddle, f)
    rows.append(("kern_twiddle_pack", t_kernel * 1e6, "post-twiddle", True))

    r2r = bench_r2r_paths(quick=quick)
    rows.append(("r2r_half_spectrum_speedup",
                 r2r["geomean_speedup"],
                 f"old_bytes={r2r['old_bytes_est']};"
                 f"new_bytes={r2r['new_bytes_est']}", False))

    payload = {
        "mode": "quick" if quick else "full",
        # interpret: true rows are CPU-emulated Pallas timings -- recorded
        # for trajectory only, NEVER comparable against the jitted refs
        "kernels": {name: {"us": us, "derived": derived, "interpret": interp}
                    for name, us, derived, interp in rows
                    if name.startswith("kern")},
        "radix4_stages": r4,
        "r2r_transform_path": dict(r2r, interpret=False),
        "normalization_folding": {
            # elementwise full-array passes after the spectral multiply:
            # seed = green multiply + one normfact multiply per r2r dir (3);
            # now = the single fused green multiply (normfacts folded in).
            "seed_elementwise_passes": 4,
            "new_elementwise_passes": 1,
        },
    }
    # anchored to the repo root so the recorded trajectory does not depend
    # on the caller's cwd (run.py may be invoked from anywhere); quick-mode
    # runs get their own file so they never clobber the recorded full-size
    # (N=256^3 acceptance) numbers
    fname = "BENCH_kernels.quick.json" if quick else "BENCH_kernels.json"
    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), fname)
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "benchmarks")
    ap_quick = "--full" not in sys.argv
    from common import emit
    emit(run(quick=ap_quick))
