"""Pallas kernels (interpret mode) vs jnp reference timings + allclose."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.fft_stockham import fft_stockham


def run(quick=True):
    import jax
    from common import time_fn
    rows = []
    rng = np.random.default_rng(0)
    n = 512 if quick else 2048
    b = 64

    re = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    im = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    t_kernel = time_fn(fft_stockham, re, im)
    t_ref = time_fn(lambda a, c: ref.fft_ref(a, c), re, im)
    gr, gi = fft_stockham(re, im)
    wr, wi = ref.fft_ref(re, im)
    err = float(jnp.max(jnp.abs(gr - wr)))
    rows.append(("kern_fft_stockham", t_kernel * 1e6,
                 f"ref_us={t_ref*1e6:.0f};maxerr={err:.1e}"))

    g = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    f = (re + 1j * im).astype(jnp.complex64)
    t_kernel = time_fn(ops.green_multiply, f, g, 0.5)
    t_ref = time_fn(lambda a, c: a * c * 0.5, f, g)
    rows.append(("kern_spectral_scale", t_kernel * 1e6,
                 f"ref_us={t_ref*1e6:.0f}"))

    t_kernel = time_fn(ops.dct2_post_twiddle, f)
    rows.append(("kern_twiddle_pack", t_kernel * 1e6, "interpret"))
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "benchmarks")
    from common import emit
    emit(run())
