"""Assignment section Roofline: aggregate the dry-run records into the
per-(arch x shape x mesh) roofline table (also rendered in EXPERIMENTS.md).
"""
from __future__ import annotations

import glob
import json
import os


def load_records(pattern="results/dryrun/*.jsonl"):
    recs = {}
    for path in sorted(glob.glob(pattern)):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if r.get("status") != "ok":
                    continue
                mesh = dict(r.get("mesh", []))
                key = (r["arch"], r["shape"],
                       "multi" if "pod" in mesh else "single",
                       r.get("comm", "a2a"))
                recs[key] = r          # later files win (hillclimbed runs)
    return recs


def run(quick=True):
    rows = []
    for (arch, shape, mesh, comm), r in sorted(load_records().items()):
        rf = r.get("roofline", {})
        if not rf:
            continue
        dom = rf.get("dominant", "?")
        frac = rf.get("roofline_frac")
        rows.append((
            f"roofline_{arch}_{shape}_{mesh}_{comm}",
            max(rf.get("t_compute_s", 0), rf.get("t_memory_s", 0),
                rf.get("t_collective_s", 0)) * 1e6,
            f"dominant={dom};frac={frac if frac is None else round(frac, 4)};"
            f"useful={rf.get('useful_flops_frac') and round(rf['useful_flops_frac'], 3)}"))
    if not rows:
        rows = [("roofline_missing", 0.0,
                 "run repro.launch.dryrun first")]
    return rows


if __name__ == "__main__":
    from common import emit
    emit(run())
