"""Paper Figs 12-15 + Table III: weak/strong scaling.

Wall-clock scaling cannot be measured on one CPU core, so this bench
combines (a) measured single-core solve times across sizes and process
grids (up to 8 host devices, subprocess) with (b) the alpha-beta model of
the topology-switch collectives to report the paper's metrics: weak
efficiency eta_w, strong speedup s_P and the serial fraction beta
(Eqs. 19-23).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np
import jax
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver

U = (BCType.UNB, BCType.UNB)
rows = []
mode = os.environ["BENCH_MODE"]
n0 = int(os.environ.get("BENCH_N", "32"))
grids = [(1,1),(1,2),(2,2),(2,4)]
for (p1, p2) in grids:
    ndev = p1 * p2
    if mode == "weak":
        # constant work per rank: n^3 scales with ranks
        n = int(round(n0 * ndev ** (1/3) / 2) * 2)
    else:
        n = n0
    mesh = jax.make_mesh((p1, p2), ("data", "model"))
    s = DistributedPoissonSolver((n, n, n), 1.0, (U, U, U), mesh=mesh,
                                 comm=CommConfig(strategy="pipelined"))
    f = np.random.default_rng(0).standard_normal((n,n,n)).astype(np.float32)
    u = s.solve(f); u.block_until_ready()
    t0 = time.perf_counter(); reps = 3
    for _ in range(reps):
        u = s.solve(f); u.block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    rows.append({"ndev": ndev, "n": n, "t": dt})
print(json.dumps(rows))
"""


def _beta(rows, weak):
    """Serial fraction from Gustafson/Amdahl fits (paper Eqs. 22/20)."""
    t0 = rows[0]["t"]
    betas = []
    for r in rows[1:]:
        rr = r["ndev"] / rows[0]["ndev"]
        if weak:
            eta = t0 / r["t"]
            beta = max((1.0 / eta - 1.0) / (rr - 1.0), 0.0)
        else:
            s = t0 / r["t"]
            beta = max((rr / s - 1.0) / (rr - 1.0), 0.0)
        betas.append(beta)
    return float(np.mean(betas))


def run(quick=True):
    out_rows = []
    for mode, fig in (("weak", "fig12"), ("strong", "fig14")):
        env = dict(os.environ, PYTHONPATH="src", BENCH_MODE=mode,
                   BENCH_N="24" if quick else "48")
        env.pop("XLA_FLAGS", None)
        out = subprocess.run([sys.executable, "-c", _SCRIPT],
                             capture_output=True, text=True, env=env)
        if out.returncode != 0:
            out_rows.append((f"{fig}_{mode}_error", 0.0,
                             out.stderr[-160:].replace("\n", " ")))
            continue
        rows = json.loads(out.stdout.strip().splitlines()[-1])
        beta = _beta(rows, weak=(mode == "weak"))
        base = rows[0]["t"]
        for r in rows:
            metric = (base / r["t"] if mode == "weak"
                      else base / r["t"])
            # throughput per rank (paper Table III normalization 14/3 for
            # the unbounded doubling)
            thr = (r["n"] ** 3 * 4 / r["t"] / r["ndev"] / 1e6) * (3 / 14)
            out_rows.append(
                (f"{fig}_{mode}_p{r['ndev']}", r["t"] * 1e6,
                 f"n={r['n']};eff_or_speedup={metric:.3f};"
                 f"thr={thr:.1f}MB/s/rank"))
        out_rows.append((f"{fig}_{mode}_beta", 0.0,
                         f"serial_fraction={beta:.4f}"))
    return out_rows


if __name__ == "__main__":
    from common import emit
    emit(run())
