"""Serving latency/throughput harness -> BENCH_serve.json.

Drives ``repro.serve.PoissonServer`` with 8 concurrent tenants bursting
requests over mixed plan keys (fully-unbounded + all-periodic pencil
plans on an 8-device host-platform (2 x 4) mesh, the bench_batch
configuration), twice:

* **batched**    -- coalescing on (``max_batch=8``): same-key requests
                    merge into one batched multi-RHS solve;
* **sequential** -- admission serialized (``max_batch=1``): every request
                    is its own solve, the pre-server baseline.

The headline is the coalescing throughput speedup (acceptance bar:
>= 1.5x -- the PR-3 batched pipeline measured 2.34x at B=8 on this mesh,
serving overhead eats some of it), plus per-tenant p50/p95/p99 latency
and the bit-exactness check: every served response must equal the
per-request reference solve EXACTLY (coalescing and rank padding never
perturb a row).

``--check`` (the CI serve job) exits non-zero when the speedup drops
below the bar or any response deviates.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.serve import PlanSpec
from repro.launch.serve import run_harness

cfg = json.loads(sys.argv[1])
n, tenants, requests = cfg["n"], cfg["tenants"], cfg["requests"]
P, U = BCType.PER, BCType.UNB
mesh = jax.make_mesh((2, 4), ("data", "model"))
kw = (("comm", CommConfig("overlap", 2)),)
specs = [
    PlanSpec(shape=(n, n, n), bcs=((U, U),) * 3, mesh=mesh, solver_kw=kw),
    PlanSpec(shape=(n, n, n), bcs=((P, P),) * 3, mesh=mesh, solver_kw=kw),
]
common = dict(n=n, tenants=tenants, requests=requests,
              max_delay_ms=cfg["max_delay_ms"], specs=specs)
batched = run_harness(max_batch=cfg["max_batch"], check=True, **common)
sequential = run_harness(max_batch=1, check=False, **common)
print("BENCH_JSON " + json.dumps(
    {"batched": batched, "sequential": sequential}, default=str))
"""


def _sweep(n, tenants, requests, max_batch, max_delay_ms):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT,
         json.dumps({"n": n, "tenants": tenants, "requests": requests,
                     "max_batch": max_batch,
                     "max_delay_ms": max_delay_ms})],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][-1]
    return json.loads(line[len("BENCH_JSON "):])


def run(quick=True):
    n, tenants = 32, 8
    requests = 6 if quick else 12
    try:
        res = _sweep(n, tenants, requests, max_batch=8, max_delay_ms=4.0)
    except RuntimeError as e:
        return [("serve_error", 0.0, str(e)[-200:])]
    batched, seq = res["batched"], res["sequential"]
    speedup = seq["wall_s"] / batched["wall_s"]
    maxdev = batched.get("max_abs_dev_vs_individual", float("nan"))
    payload = {
        "mode": "quick" if quick else "full",
        "grid": n, "mesh": [2, 4], "bcs": ["unb", "per"],
        "comm": "overlap:2", "tenants": tenants,
        "requests_per_tenant": requests, "max_batch": 8,
        "headline": {
            "coalescing_speedup_vs_sequential": speedup,
            "batched_rps": batched["throughput_rps"],
            "sequential_rps": seq["throughput_rps"],
            "mean_batch_occupancy": batched["mean_batch_occupancy"],
            "max_abs_dev_vs_individual": maxdev,
        },
        "batched": batched, "sequential": seq,
    }
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_serve.json"), "w") as fh:
        json.dump(payload, fh, indent=2, default=str)
    rows = [("serve_batched", batched["wall_s"] * 1e6,
             f"{batched['throughput_rps']:.0f}rps"),
            ("serve_sequential", seq["wall_s"] * 1e6,
             f"{seq['throughput_rps']:.0f}rps"),
            ("serve_speedup", 0.0, f"{speedup:.2f}x_vs_sequential"),
            ("serve_maxdev", 0.0, f"{maxdev:.1e}")]
    for name, t in sorted(batched["tenants_stats"].items()):
        rows.append((f"serve_{name}", t["p50_ms"] * 1e3,
                     f"p95={t['p95_ms']:.1f}ms_p99={t['p99_ms']:.1f}ms"))
    return rows


def check(path="BENCH_serve.json") -> int:
    """CI gate: coalescing >= 1.5x over sequential admission AND every
    response bit-exact vs the per-request reference solves."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, path)) as fh:
        payload = json.load(fh)
    h = payload["headline"]
    bad = []
    if not h["coalescing_speedup_vs_sequential"] >= 1.5:
        bad.append(f"coalescing speedup "
                   f"{h['coalescing_speedup_vs_sequential']:.2f}x < 1.5x")
    if not float(h["max_abs_dev_vs_individual"]) == 0.0:
        bad.append(f"served responses deviate from per-request solves "
                   f"(max |dev| {h['max_abs_dev_vs_individual']})")
    for msg in bad:
        print(f"CHECK FAIL: {msg}", file=sys.stderr)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit
    out_rows = run(quick="--full" not in sys.argv)
    emit(out_rows)
    if any(name == "serve_error" for name, _, _ in out_rows):
        sys.exit(1)
    if "--check" in sys.argv:
        sys.exit(check())
