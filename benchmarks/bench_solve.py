"""Dense-vs-pruned + layout-scheduling solve benchmarks.

Part 1 (the Hockney-doubling study, PR 4): every unbounded direction is a
length-2n DFT of a signal whose second half is identically zero.
``doubling="upfront"`` (dense) materializes that padding in the input
field -- the textbook Hockney reference; ``doubling="deferred"`` (pruned,
the default) keeps every axis at its live extent outside its own 1-D
transform.  Three cases, both modes each:

  unb   all-unbounded 3-D (the paper's headline; expected >= 1.3x pruned)
  mix   unbounded x periodic x unbounded
  per   all-periodic (doubling is a no-op: parity expected, +-5%)

Part 2 (the layout-scheduling study, DESIGN.md #9): the ALL-PERIODIC case
-- where pruning gave no win -- under ``relayout="scheduled"`` (plan-time
layout schedule + execution-order choice, relayouts folded into the
topology switches, both fold sides timed) vs the PR-4 pipeline
(``relayout="baseline"``, ``order_policy="natural"``: per-direction
moveaxis round trips).  Benched at n=64, where the solve is
bandwidth-bound and the removed relayout traffic shows end-to-end
(measured 1.2-1.6x on the 8-device host mesh); ``hlo_stats.
transpose_stats`` of both lowered pipelines is recorded alongside -- the
scheduled one must show ZERO standalone transposes between stages.

Runs on an 8-device host mesh in subprocesses; writes ``BENCH_solve.json``
(quick mode included -- the acceptance trajectory is recorded from host
meshes).  ``--check`` exits nonzero when the pruned solve is SLOWER than
dense on the all-unbounded case, parity is broken on all-periodic, the
scheduled pipeline emits standalone transposes, or it grossly regresses
the baseline (< 0.9x; the timing floor is loose on purpose -- shared CI
runners are noisy, the structural transpose gate is the deterministic
one) -- the CI perf-regression guard.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
import jax, jax.numpy as jnp
sys.path.insert(0, "benchmarks")
from common import interleaved_min
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver
from repro.launch.hlo_stats import comm_bytes_stats

n = int(os.environ.get("BENCH_N", "32"))
reps = int(os.environ.get("BENCH_REPS", "41"))
U, P = (BCType.UNB, BCType.UNB), (BCType.PER, BCType.PER)
CASES = {"unb": (U, U, U), "mix": (U, P, U), "per": (P, P, P)}
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
f = rng.standard_normal((n, n, n)).astype(np.float32)
out = {}
for case, bcs in CASES.items():
    row = {}
    ref = {}
    solvers = {}
    for doubling in ("deferred", "upfront"):
        s = DistributedPoissonSolver((n, n, n), 1.0, bcs, mesh=mesh,
                                     comm=CommConfig("a2a"),
                                     doubling=doubling)
        u = s.solve(f); u.block_until_ready()   # compile + warm
        ref[doubling] = np.asarray(u)
        solvers[doubling] = s
        bstats = comm_bytes_stats(s.lower().as_text())
        row[doubling] = {
            "first_switch_bytes": bstats["first_bytes"],
            "total_comm_bytes": bstats["total_bytes"],
        }
    best = interleaved_min(
        {k: (lambda s=s: s.solve(f)) for k, s in solvers.items()},
        reps=reps)
    for doubling in solvers:
        row[doubling]["us"] = best[doubling] * 1e6
    err = float(np.max(np.abs(ref["deferred"] - ref["upfront"])))
    row["pruned_speedup"] = row["upfront"]["us"] / row["deferred"]["us"]
    row["comm_bytes_ratio"] = (
        row["upfront"]["total_comm_bytes"]
        / max(row["deferred"]["total_comm_bytes"], 1))
    row["maxerr_pruned_vs_dense"] = err
    out[case] = row
print("BENCH_JSON " + json.dumps(out))
"""


_RELAYOUT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
import jax, jax.numpy as jnp
sys.path.insert(0, "benchmarks")
from common import interleaved_min
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver
from repro.launch.hlo_stats import transpose_stats

n = int(os.environ.get("BENCH_RELAYOUT_N", "64"))
reps = int(os.environ.get("BENCH_REPS", "41"))
P2 = (BCType.PER, BCType.PER)
bcs = (P2, P2, P2)                   # the case where pruning gave no win
mesh = jax.make_mesh((2, 4), ("data", "model"))
f = np.random.default_rng(0).standard_normal((n, n, n)).astype(np.float32)

# PR-4 pipeline: moveaxis round trips, historical ascending order
pr4 = DistributedPoissonSolver((n, n, n), 1.0, bcs, mesh=mesh,
                               comm=CommConfig("a2a"), relayout="baseline",
                               order_policy="natural")
sched = {fold: DistributedPoissonSolver(
             (n, n, n), 1.0, bcs, mesh=mesh,
             comm=CommConfig("a2a", 1, fold), relayout="scheduled")
         for fold in ("pack", "unpack")}

row = {"grid": n, "case": "per", "comm": "a2a"}
ref = np.asarray(pr4.solve(f))
scale = float(np.max(np.abs(ref)))
for fold, s in sched.items():
    # scheduled plans also reorder the execution within BC categories
    # (order_policy="layout"), so vs the natural-order PR-4 pipeline the
    # match is floating-point equivalence, not bit-exactness (the
    # bit-exact scheduled-vs-baseline net at FIXED order lives in
    # tests/test_layout.py)
    err = float(np.max(np.abs(np.asarray(s.solve(f)) - ref)))
    row[f"relerr_{fold}_vs_pr4"] = err / scale
stats = {"pr4": transpose_stats(pr4.lower().as_text())}
for fold, s in sched.items():
    stats[f"scheduled_{fold}"] = transpose_stats(s.lower().as_text())
row["transpose_stats"] = stats

fns = {"pr4": lambda: pr4.solve(f)}
for fold, s in sched.items():
    fns[f"scheduled_{fold}"] = (lambda s=s: s.solve(f))
best = interleaved_min(fns, reps=reps)
for k, v in best.items():
    row[k + "_us"] = v * 1e6
sched_best = min(best["scheduled_pack"], best["scheduled_unpack"])
row["best_fold"] = min(("pack", "unpack"),
                       key=lambda fd: best[f"scheduled_{fd}"])
row["scheduled_speedup"] = best["pr4"] / sched_best
print("BENCH_JSON " + json.dumps(row))
"""


def _run_sub(script, env_extra):
    env = dict(os.environ, PYTHONPATH="src", **env_extra)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_COMM_CACHE", None)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][-1]
    return json.loads(line[len("BENCH_JSON "):])


def _sweep(n, reps):
    return _run_sub(_SCRIPT, {"BENCH_N": str(n), "BENCH_REPS": str(reps)})


def _relayout_sweep(n, reps):
    return _run_sub(_RELAYOUT_SCRIPT, {"BENCH_RELAYOUT_N": str(n),
                                       "BENCH_REPS": str(reps)})


def run(quick=True, check=False):
    n = 32 if quick else 64
    try:
        cases = _sweep(n, 41 if quick else 21)
        # layout-scheduling study: always n=64 (bandwidth-bound, where the
        # removed relayout traffic shows end-to-end; at 32^3 per-op
        # dispatch overhead hides it on host meshes)
        relayout = _relayout_sweep(64, 61 if quick else 41)
    except RuntimeError as e:
        if check:
            # the perf gate must never go green because the bench itself
            # failed to run -- surface the subprocess error as the failure
            raise
        # keep the CSV contract: one single-line row (the tail of the
        # subprocess stderr is a multi-line traceback)
        msg = " ".join(str(e)[-200:].split())
        return [("solve_pruned_error", 0.0, msg.replace(",", ";"))]
    payload = {"mode": "quick" if quick else "full", "grid": n,
               "mesh": [2, 4], "dtype": "float32", "comm": "a2a",
               "cases": cases, "relayout": relayout}
    # BENCH_solve.json is written from quick mode too: the acceptance
    # trajectory (pruned >= 1.3x on all-unbounded, parity on periodic) is
    # recorded from host meshes, where quick grids already saturate the
    # doubling effect
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_solve.json"), "w") as fh:
        json.dump(payload, fh, indent=2)
    rows = []
    for case, r in cases.items():
        rows.append((f"solve_{case}_pruned", r["deferred"]["us"],
                     f"dense_us={r['upfront']['us']:.0f};"
                     f"speedup={r['pruned_speedup']:.2f};"
                     f"comm_ratio={r['comm_bytes_ratio']:.2f};"
                     f"maxerr={r['maxerr_pruned_vs_dense']:.1e}"))
    sb = relayout[f"scheduled_{relayout['best_fold']}_us"]
    rows.append((
        "solve_per_relayout_scheduled", sb,
        f"pr4_us={relayout['pr4_us']:.0f};"
        f"speedup={relayout['scheduled_speedup']:.2f};"
        f"fold={relayout['best_fold']};"
        f"standalone_T={relayout['transpose_stats']['scheduled_pack']['standalone']}"))
    if check:
        unb, per = cases["unb"], cases["per"]
        problems = []
        # the acceptance floor is >= 1.3x; measured ~3x, so this gate has
        # real headroom without flaking on shared CI runners
        if unb["pruned_speedup"] < 1.3:
            problems.append(
                f"unb pruned speedup {unb['pruned_speedup']:.2f} < 1.3")
        if (unb["deferred"]["first_switch_bytes"]
                >= unb["upfront"]["first_switch_bytes"]):
            problems.append(
                f"first-switch bytes not reduced: "
                f"{unb['deferred']['first_switch_bytes']} vs dense "
                f"{unb['upfront']['first_switch_bytes']}")
        # periodic plans are bit-identical, so the recorded artifact shows
        # ~1.00x; the CI band is wider (+-20%) purely for shared-runner
        # timer noise -- it still catches a pruning bug leaking work into
        # the periodic path
        if not 0.8 <= per["pruned_speedup"] <= 1.25:
            problems.append(
                f"all-periodic parity broken: {per['pruned_speedup']:.2f}")
        # pruned vs dense is deterministic bit-exactness on xla -- a hard
        # gate, timing-independent
        for case, r in cases.items():
            if r["maxerr_pruned_vs_dense"] != 0.0:
                problems.append(
                    f"{case} pruned != dense "
                    f"(maxerr {r['maxerr_pruned_vs_dense']:.3e})")
        # layout-scheduling gates: the STRUCTURAL one is deterministic --
        # the scheduled pipeline must emit zero standalone transposes
        # between stages on lowered HLO (both fold sides) and stay
        # bit-exact vs the PR-4 pipeline; the timing floor is loose (0.9x)
        # because shared runners are noisy -- the recorded artifact is
        # where the 1.2x+ trajectory lives (measured 1.2-1.6x at n=64)
        ts = relayout["transpose_stats"]
        for variant in ("scheduled_pack", "scheduled_unpack"):
            if ts[variant]["standalone"] != 0:
                problems.append(
                    f"{variant} emits {ts[variant]['standalone']} "
                    "standalone transposes between stages")
        if ts["pr4"]["standalone"] == 0:
            problems.append(
                "baseline census lost its standalone transposes -- "
                "transpose_stats is no longer discriminating")
        for fold in ("pack", "unpack"):
            # fp-equivalence only: the scheduled plan reorders execution
            # within BC categories, so roundoff differs from natural order
            if relayout[f"relerr_{fold}_vs_pr4"] > 1e-5:
                problems.append(
                    f"scheduled({fold}) != PR-4 pipeline (relerr "
                    f"{relayout[f'relerr_{fold}_vs_pr4']:.3e})")
        if relayout["scheduled_speedup"] < 0.9:
            problems.append(
                f"layout-scheduled solve regressed: "
                f"{relayout['scheduled_speedup']:.2f}x vs PR-4")
        if problems:
            raise SystemExit("perf regression: " + "; ".join(problems))
    return rows


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit
    emit(run(quick="--full" not in sys.argv, check="--check" in sys.argv))
