"""Dense-vs-pruned unbounded solve benchmark (the Hockney-doubling study).

The paper's headline workload: every unbounded direction is a length-2n DFT
of a signal whose second half is identically zero.  ``doubling="upfront"``
(dense) materializes that padding in the input field -- the textbook
Hockney reference, where early transforms run over doubled row counts and
the topology switches ship doubled extents.  ``doubling="deferred"``
(pruned, the default) keeps every axis at its live extent outside its own
1-D transform.  Three cases, both modes each:

  unb   all-unbounded 3-D (the paper's headline; expected >= 1.3x pruned)
  mix   unbounded x periodic x unbounded
  per   all-periodic (doubling is a no-op: parity expected, +-5%)

Runs on an 8-device host mesh in a subprocess; writes ``BENCH_solve.json``
(quick mode included -- the acceptance trajectory is recorded from host
meshes).  ``--check`` exits nonzero when the pruned solve is SLOWER than
dense on the all-unbounded case or parity is broken on all-periodic -- the
CI perf-regression guard.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
import jax, jax.numpy as jnp
sys.path.insert(0, "benchmarks")
from common import interleaved_min
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver
from repro.launch.hlo_stats import comm_bytes_stats

n = int(os.environ.get("BENCH_N", "32"))
reps = int(os.environ.get("BENCH_REPS", "41"))
U, P = (BCType.UNB, BCType.UNB), (BCType.PER, BCType.PER)
CASES = {"unb": (U, U, U), "mix": (U, P, U), "per": (P, P, P)}
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
f = rng.standard_normal((n, n, n)).astype(np.float32)
out = {}
for case, bcs in CASES.items():
    row = {}
    ref = {}
    solvers = {}
    for doubling in ("deferred", "upfront"):
        s = DistributedPoissonSolver((n, n, n), 1.0, bcs, mesh=mesh,
                                     comm=CommConfig("a2a"),
                                     doubling=doubling)
        u = s.solve(f); u.block_until_ready()   # compile + warm
        ref[doubling] = np.asarray(u)
        solvers[doubling] = s
        bstats = comm_bytes_stats(s.lower().as_text())
        row[doubling] = {
            "first_switch_bytes": bstats["first_bytes"],
            "total_comm_bytes": bstats["total_bytes"],
        }
    best = interleaved_min(
        {k: (lambda s=s: s.solve(f)) for k, s in solvers.items()},
        reps=reps)
    for doubling in solvers:
        row[doubling]["us"] = best[doubling] * 1e6
    err = float(np.max(np.abs(ref["deferred"] - ref["upfront"])))
    row["pruned_speedup"] = row["upfront"]["us"] / row["deferred"]["us"]
    row["comm_bytes_ratio"] = (
        row["upfront"]["total_comm_bytes"]
        / max(row["deferred"]["total_comm_bytes"], 1))
    row["maxerr_pruned_vs_dense"] = err
    out[case] = row
print("BENCH_JSON " + json.dumps(out))
"""


def _sweep(n, reps):
    env = dict(os.environ, PYTHONPATH="src", BENCH_N=str(n),
               BENCH_REPS=str(reps))
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_COMM_CACHE", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][-1]
    return json.loads(line[len("BENCH_JSON "):])


def run(quick=True, check=False):
    n = 32 if quick else 64
    try:
        cases = _sweep(n, 41 if quick else 21)
    except RuntimeError as e:
        if check:
            # the perf gate must never go green because the bench itself
            # failed to run -- surface the subprocess error as the failure
            raise
        # keep the CSV contract: one single-line row (the tail of the
        # subprocess stderr is a multi-line traceback)
        msg = " ".join(str(e)[-200:].split())
        return [("solve_pruned_error", 0.0, msg.replace(",", ";"))]
    payload = {"mode": "quick" if quick else "full", "grid": n,
               "mesh": [2, 4], "dtype": "float32", "comm": "a2a",
               "cases": cases}
    # BENCH_solve.json is written from quick mode too: the acceptance
    # trajectory (pruned >= 1.3x on all-unbounded, parity on periodic) is
    # recorded from host meshes, where quick grids already saturate the
    # doubling effect
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_solve.json"), "w") as fh:
        json.dump(payload, fh, indent=2)
    rows = []
    for case, r in cases.items():
        rows.append((f"solve_{case}_pruned", r["deferred"]["us"],
                     f"dense_us={r['upfront']['us']:.0f};"
                     f"speedup={r['pruned_speedup']:.2f};"
                     f"comm_ratio={r['comm_bytes_ratio']:.2f};"
                     f"maxerr={r['maxerr_pruned_vs_dense']:.1e}"))
    if check:
        unb, per = cases["unb"], cases["per"]
        problems = []
        # the acceptance floor is >= 1.3x; measured ~3x, so this gate has
        # real headroom without flaking on shared CI runners
        if unb["pruned_speedup"] < 1.3:
            problems.append(
                f"unb pruned speedup {unb['pruned_speedup']:.2f} < 1.3")
        if (unb["deferred"]["first_switch_bytes"]
                >= unb["upfront"]["first_switch_bytes"]):
            problems.append(
                f"first-switch bytes not reduced: "
                f"{unb['deferred']['first_switch_bytes']} vs dense "
                f"{unb['upfront']['first_switch_bytes']}")
        # periodic plans are bit-identical, so the recorded artifact shows
        # ~1.00x; the CI band is wider (+-20%) purely for shared-runner
        # timer noise -- it still catches a pruning bug leaking work into
        # the periodic path
        if not 0.8 <= per["pruned_speedup"] <= 1.25:
            problems.append(
                f"all-periodic parity broken: {per['pruned_speedup']:.2f}")
        # pruned vs dense is deterministic bit-exactness on xla -- a hard
        # gate, timing-independent
        for case, r in cases.items():
            if r["maxerr_pruned_vs_dense"] != 0.0:
                problems.append(
                    f"{case} pruned != dense "
                    f"(maxerr {r['maxerr_pruned_vs_dense']:.3e})")
        if problems:
            raise SystemExit("perf regression: " + "; ".join(problems))
    return rows


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit
    emit(run(quick="--full" not in sys.argv, check="--check" in sys.argv))
