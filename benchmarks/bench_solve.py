"""Dense-vs-pruned + layout-scheduling solve benchmarks.

Part 1 (the Hockney-doubling study, PR 4): every unbounded direction is a
length-2n DFT of a signal whose second half is identically zero.
``doubling="upfront"`` (dense) materializes that padding in the input
field -- the textbook Hockney reference; ``doubling="deferred"`` (pruned,
the default) keeps every axis at its live extent outside its own 1-D
transform.  Three cases, both modes each:

  unb   all-unbounded 3-D (the paper's headline; expected >= 1.3x pruned)
  mix   unbounded x periodic x unbounded
  per   all-periodic (doubling is a no-op: parity expected, +-5%)

Part 2 (the layout-scheduling study, DESIGN.md #9): the ALL-PERIODIC case
-- where pruning gave no win -- under ``relayout="scheduled"`` (plan-time
layout schedule + execution-order choice, relayouts folded into the
topology switches, both fold sides timed) vs the PR-4 pipeline
(``relayout="baseline"``, ``order_policy="natural"``: per-direction
moveaxis round trips).  Benched at n=64, where the solve is
bandwidth-bound and the removed relayout traffic shows end-to-end
(measured 1.2-1.6x on the 8-device host mesh); ``hlo_stats.
transpose_stats`` of both lowered pipelines is recorded alongside -- the
scheduled one must show ZERO standalone transposes between stages.

Part 3 (the ABFT overhead study, DESIGN.md #13): ``verify="abft"`` vs
verify-off on the same three BC rows.  The end-to-end linearity sandwich
costs three host BLAS streams over the field (probe-contract the output,
dot the weight against the input), so each row's grid is sized to put
the verify-off solve in the 13-40 ms band where that cost is the
measurement, not dispatch noise.  Each abft rep is bracketed by two
verify-off reps and the overhead is the LOWER QUARTILE of the per-rep
ratios: the bracket cancels this runner's multi-second slow phases to
first order, and the quartile reads the marginal cost off the
clean-phase reps while still shifting with any real regression.
``--check`` gates overhead <= 5% per row, bit-exactness of the clean
path (the verify-off jit IS the abft jit), and zero integrity records
over the timing reps (the clean false-positive soak).

Runs on an 8-device host mesh in subprocesses; writes ``BENCH_solve.json``
(quick mode included -- the acceptance trajectory is recorded from host
meshes).  ``--check`` exits nonzero when the pruned solve is SLOWER than
dense on the all-unbounded case, parity is broken on all-periodic, the
scheduled pipeline emits standalone transposes, or it grossly regresses
the baseline (< 0.9x; the timing floor is loose on purpose -- shared CI
runners are noisy, the structural transpose gate is the deterministic
one) -- the CI perf-regression guard.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
import jax, jax.numpy as jnp
sys.path.insert(0, "benchmarks")
from common import interleaved_min
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver
from repro.launch.hlo_stats import comm_bytes_stats

n = int(os.environ.get("BENCH_N", "32"))
reps = int(os.environ.get("BENCH_REPS", "41"))
U, P = (BCType.UNB, BCType.UNB), (BCType.PER, BCType.PER)
CASES = {"unb": (U, U, U), "mix": (U, P, U), "per": (P, P, P)}
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
f = rng.standard_normal((n, n, n)).astype(np.float32)
out = {}
for case, bcs in CASES.items():
    row = {}
    ref = {}
    solvers = {}
    for doubling in ("deferred", "upfront"):
        s = DistributedPoissonSolver((n, n, n), 1.0, bcs, mesh=mesh,
                                     comm=CommConfig("a2a"),
                                     doubling=doubling)
        u = s.solve(f); u.block_until_ready()   # compile + warm
        ref[doubling] = np.asarray(u)
        solvers[doubling] = s
        bstats = comm_bytes_stats(s.lower().as_text())
        row[doubling] = {
            "first_switch_bytes": bstats["first_bytes"],
            "total_comm_bytes": bstats["total_bytes"],
        }
    best = interleaved_min(
        {k: (lambda s=s: s.solve(f)) for k, s in solvers.items()},
        reps=reps)
    for doubling in solvers:
        row[doubling]["us"] = best[doubling] * 1e6
    err = float(np.max(np.abs(ref["deferred"] - ref["upfront"])))
    row["pruned_speedup"] = row["upfront"]["us"] / row["deferred"]["us"]
    row["comm_bytes_ratio"] = (
        row["upfront"]["total_comm_bytes"]
        / max(row["deferred"]["total_comm_bytes"], 1))
    row["maxerr_pruned_vs_dense"] = err
    out[case] = row
print("BENCH_JSON " + json.dumps(out))
"""


_ABFT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys, time
import numpy as np
import jax, jax.numpy as jnp
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver

reps = int(os.environ.get("BENCH_REPS", "61"))
U, P = (BCType.UNB, BCType.UNB), (BCType.PER, BCType.PER)
# per-row grids chosen so every verify-off solve sits in the same
# 13-60 ms wall-clock band on the 8-device host mesh: the sandwich cost
# is two BLAS streams over the field (~0.3-1.3 ms), so tiny grids would
# measure fixed dispatch noise, not the check (the all-periodic solve is
# ~3x faster per point than the doubled unbounded cases, hence its
# larger grid)
CASES = {"unb": ((U, U, U), 96), "mix": ((U, P, U), 96),
         "per": ((P, P, P), 128)}
mesh = jax.make_mesh((2, 4), ("data", "model"))
out = {}
for case, (bcs, n) in CASES.items():
    s = DistributedPoissonSolver((n, n, n), 1.0, bcs, mesh=mesh,
                                 comm=CommConfig("a2a"))
    f = np.random.default_rng(0).standard_normal((n, n, n)).astype(
        np.float32)
    u_off = np.asarray(s.solve(f))             # compile + warm
    u_abft = np.asarray(s.solve(f, verify="abft"))

    def t(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return time.perf_counter() - t0

    # sandwich-control estimator: this box drifts through multi-second
    # slow phases (several times the check's ~1 ms cost), so a ratio of
    # independent mins flakes.  Bracketing every abft rep between two
    # verify-off reps cancels the phase to first order (all three solves
    # share a ~100 ms window), and the LOWER QUARTILE of the per-rep
    # ratios reads the marginal check cost from the clean-phase reps --
    # a real regression (more streams per check) shifts the whole ratio
    # distribution, quartile included, so the gate still catches it
    ratios, offs = [], []
    off_prev = t(lambda: s.solve(f))
    for _ in range(reps):
        ta = t(lambda: s.solve(f, verify="abft"))
        off_next = t(lambda: s.solve(f))
        ratios.append(ta / ((off_prev + off_next) / 2.0))
        offs.append(off_next)
        off_prev = off_next
    out[case] = {
        "grid": n,
        "off_us": float(np.median(offs)) * 1e6,
        "overhead": float(np.percentile(ratios, 25)) - 1.0,
        "overhead_med": float(np.median(ratios)) - 1.0,
        # structural gates: verify="abft" shares the verify-off jit, so
        # the clean output must be bit-identical; the reps above double
        # as a clean soak -- any integrity record is a false positive
        "bitexact": bool(np.array_equal(u_off, u_abft)),
        "false_positives": len(s.stats.get("integrity", [])),
        "verify_failures": int(s.stats.get("verify_failures", 0)),
    }
print("BENCH_JSON " + json.dumps(out))
"""


_RELAYOUT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import numpy as np
import jax, jax.numpy as jnp
sys.path.insert(0, "benchmarks")
from common import interleaved_min
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver
from repro.launch.hlo_stats import transpose_stats

n = int(os.environ.get("BENCH_RELAYOUT_N", "64"))
reps = int(os.environ.get("BENCH_REPS", "41"))
P2 = (BCType.PER, BCType.PER)
bcs = (P2, P2, P2)                   # the case where pruning gave no win
mesh = jax.make_mesh((2, 4), ("data", "model"))
f = np.random.default_rng(0).standard_normal((n, n, n)).astype(np.float32)

# PR-4 pipeline: moveaxis round trips, historical ascending order
pr4 = DistributedPoissonSolver((n, n, n), 1.0, bcs, mesh=mesh,
                               comm=CommConfig("a2a"), relayout="baseline",
                               order_policy="natural")
sched = {fold: DistributedPoissonSolver(
             (n, n, n), 1.0, bcs, mesh=mesh,
             comm=CommConfig("a2a", 1, fold), relayout="scheduled")
         for fold in ("pack", "unpack")}

row = {"grid": n, "case": "per", "comm": "a2a"}
ref = np.asarray(pr4.solve(f))
scale = float(np.max(np.abs(ref)))
for fold, s in sched.items():
    # scheduled plans also reorder the execution within BC categories
    # (order_policy="layout"), so vs the natural-order PR-4 pipeline the
    # match is floating-point equivalence, not bit-exactness (the
    # bit-exact scheduled-vs-baseline net at FIXED order lives in
    # tests/test_layout.py)
    err = float(np.max(np.abs(np.asarray(s.solve(f)) - ref)))
    row[f"relerr_{fold}_vs_pr4"] = err / scale
stats = {"pr4": transpose_stats(pr4.lower().as_text())}
for fold, s in sched.items():
    stats[f"scheduled_{fold}"] = transpose_stats(s.lower().as_text())
row["transpose_stats"] = stats

fns = {"pr4": lambda: pr4.solve(f)}
for fold, s in sched.items():
    fns[f"scheduled_{fold}"] = (lambda s=s: s.solve(f))
best = interleaved_min(fns, reps=reps)
for k, v in best.items():
    row[k + "_us"] = v * 1e6
sched_best = min(best["scheduled_pack"], best["scheduled_unpack"])
row["best_fold"] = min(("pack", "unpack"),
                       key=lambda fd: best[f"scheduled_{fd}"])
row["scheduled_speedup"] = best["pr4"] / sched_best
print("BENCH_JSON " + json.dumps(row))
"""


def _run_sub(script, env_extra):
    env = dict(os.environ, PYTHONPATH="src", **env_extra)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_COMM_CACHE", None)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON ")][-1]
    return json.loads(line[len("BENCH_JSON "):])


def _sweep(n, reps):
    return _run_sub(_SCRIPT, {"BENCH_N": str(n), "BENCH_REPS": str(reps)})


def _relayout_sweep(n, reps):
    return _run_sub(_RELAYOUT_SCRIPT, {"BENCH_RELAYOUT_N": str(n),
                                       "BENCH_REPS": str(reps)})


def _abft_sweep(reps):
    return _run_sub(_ABFT_SCRIPT, {"BENCH_REPS": str(reps)})


def run(quick=True, check=False):
    n = 32 if quick else 64
    try:
        cases = _sweep(n, 41 if quick else 21)
        # layout-scheduling study: always n=64 (bandwidth-bound, where the
        # removed relayout traffic shows end-to-end; at 32^3 per-op
        # dispatch overhead hides it on host meshes)
        relayout = _relayout_sweep(64, 61 if quick else 41)
        # ABFT overhead study (DESIGN.md #13): verify="abft" vs verify-off
        # on the pruned / mixed / periodic rows (sandwich-control ratios)
        abft = _abft_sweep(31 if quick else 41)
        if check and any(r["overhead"] > 0.05 for r in abft.values()):
            # even the sandwich estimator can land entirely inside one of
            # this runner's sustained slow phases: one resample before
            # gating (a real regression fails both samples; structural
            # fields -- bit-exactness, false positives -- merge strictly)
            retry = _abft_sweep(31 if quick else 41)
            for case, r2 in retry.items():
                r = abft[case]
                if r2["overhead"] < r["overhead"]:
                    r["off_us"] = r2["off_us"]
                    r["overhead"] = r2["overhead"]
                    r["overhead_med"] = r2["overhead_med"]
                r["bitexact"] = r["bitexact"] and r2["bitexact"]
                r["false_positives"] += r2["false_positives"]
                r["verify_failures"] += r2["verify_failures"]
    except RuntimeError as e:
        if check:
            # the perf gate must never go green because the bench itself
            # failed to run -- surface the subprocess error as the failure
            raise
        # keep the CSV contract: one single-line row (the tail of the
        # subprocess stderr is a multi-line traceback)
        msg = " ".join(str(e)[-200:].split())
        return [("solve_pruned_error", 0.0, msg.replace(",", ";"))]
    payload = {"mode": "quick" if quick else "full", "grid": n,
               "mesh": [2, 4], "dtype": "float32", "comm": "a2a",
               "cases": cases, "relayout": relayout, "abft": abft}
    # BENCH_solve.json is written from quick mode too: the acceptance
    # trajectory (pruned >= 1.3x on all-unbounded, parity on periodic) is
    # recorded from host meshes, where quick grids already saturate the
    # doubling effect
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_solve.json"), "w") as fh:
        json.dump(payload, fh, indent=2)
    rows = []
    for case, r in cases.items():
        rows.append((f"solve_{case}_pruned", r["deferred"]["us"],
                     f"dense_us={r['upfront']['us']:.0f};"
                     f"speedup={r['pruned_speedup']:.2f};"
                     f"comm_ratio={r['comm_bytes_ratio']:.2f};"
                     f"maxerr={r['maxerr_pruned_vs_dense']:.1e}"))
    for case, r in abft.items():
        rows.append((f"solve_{case}_abft",
                     r["off_us"] * (1.0 + r["overhead"]),
                     f"off_us={r['off_us']:.0f};"
                     f"overhead={r['overhead']:+.1%};"
                     f"overhead_med={r['overhead_med']:+.1%};"
                     f"grid={r['grid']};"
                     f"bitexact={r['bitexact']};"
                     f"false_pos={r['false_positives']}"))
    sb = relayout[f"scheduled_{relayout['best_fold']}_us"]
    rows.append((
        "solve_per_relayout_scheduled", sb,
        f"pr4_us={relayout['pr4_us']:.0f};"
        f"speedup={relayout['scheduled_speedup']:.2f};"
        f"fold={relayout['best_fold']};"
        f"standalone_T={relayout['transpose_stats']['scheduled_pack']['standalone']}"))
    if check:
        unb, per = cases["unb"], cases["per"]
        problems = []
        # the acceptance floor is >= 1.3x; measured ~3x, so this gate has
        # real headroom without flaking on shared CI runners
        if unb["pruned_speedup"] < 1.3:
            problems.append(
                f"unb pruned speedup {unb['pruned_speedup']:.2f} < 1.3")
        if (unb["deferred"]["first_switch_bytes"]
                >= unb["upfront"]["first_switch_bytes"]):
            problems.append(
                f"first-switch bytes not reduced: "
                f"{unb['deferred']['first_switch_bytes']} vs dense "
                f"{unb['upfront']['first_switch_bytes']}")
        # periodic plans are bit-identical, so the recorded artifact shows
        # ~1.00x; the CI band is wider (+-20%) purely for shared-runner
        # timer noise -- it still catches a pruning bug leaking work into
        # the periodic path
        if not 0.8 <= per["pruned_speedup"] <= 1.25:
            problems.append(
                f"all-periodic parity broken: {per['pruned_speedup']:.2f}")
        # pruned vs dense is deterministic bit-exactness on xla -- a hard
        # gate, timing-independent
        for case, r in cases.items():
            if r["maxerr_pruned_vs_dense"] != 0.0:
                problems.append(
                    f"{case} pruned != dense "
                    f"(maxerr {r['maxerr_pruned_vs_dense']:.3e})")
        # layout-scheduling gates: the STRUCTURAL one is deterministic --
        # the scheduled pipeline must emit zero standalone transposes
        # between stages on lowered HLO (both fold sides) and stay
        # bit-exact vs the PR-4 pipeline; the timing floor is loose (0.9x)
        # because shared runners are noisy -- the recorded artifact is
        # where the 1.2x+ trajectory lives (measured 1.2-1.6x at n=64)
        ts = relayout["transpose_stats"]
        for variant in ("scheduled_pack", "scheduled_unpack"):
            if ts[variant]["standalone"] != 0:
                problems.append(
                    f"{variant} emits {ts[variant]['standalone']} "
                    "standalone transposes between stages")
        if ts["pr4"]["standalone"] == 0:
            problems.append(
                "baseline census lost its standalone transposes -- "
                "transpose_stats is no longer discriminating")
        for fold in ("pack", "unpack"):
            # fp-equivalence only: the scheduled plan reorders execution
            # within BC categories, so roundoff differs from natural order
            if relayout[f"relerr_{fold}_vs_pr4"] > 1e-5:
                problems.append(
                    f"scheduled({fold}) != PR-4 pipeline (relerr "
                    f"{relayout[f'relerr_{fold}_vs_pr4']:.3e})")
        if relayout["scheduled_speedup"] < 0.9:
            problems.append(
                f"layout-scheduled solve regressed: "
                f"{relayout['scheduled_speedup']:.2f}x vs PR-4")
        # ABFT gates (DESIGN.md #13): <= 5% end-to-end overhead for
        # verify="abft" on every row (lower-quartile sandwich-control
        # ratios -- the check costs three BLAS streams, measured 1-4% in
        # the 13-40 ms solve band), the clean path bit-exact with checks
        # off, and the timing reps doubling as a zero-false-positive
        # clean soak
        for case, r in abft.items():
            if r["overhead"] > 0.05:
                problems.append(
                    f"abft overhead on {case} row "
                    f"{r['overhead']:+.1%} > 5%")
            if not r["bitexact"]:
                problems.append(
                    f"abft clean solve not bit-exact on {case} row")
            if r["false_positives"] or r["verify_failures"]:
                problems.append(
                    f"abft false positives on clean {case} soak: "
                    f"{r['false_positives']} records, "
                    f"{r['verify_failures']} verify failures")
        if problems:
            raise SystemExit("perf regression: " + "; ".join(problems))
    return rows


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit
    emit(run(quick="--full" not in sys.argv, check="--check" in sys.argv))
