"""LM-side throughput micro-bench: smoke-size train/decode steps per arch
family (reference numbers for the CPU validation environment)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def run(quick=True):
    from common import time_fn
    from repro.configs import get_smoke
    from repro.data.pipeline import synthetic_batch
    from repro.models import transformer as tf
    from repro.training.train_step import make_train_state, train_step_fn

    rows = []
    archs = ["qwen3-0.6b", "qwen3-moe-235b-a22b", "mamba2-2.7b",
             "recurrentgemma-9b"] if quick else [
        "qwen3-0.6b", "qwen3-moe-235b-a22b", "mamba2-2.7b",
        "recurrentgemma-9b", "whisper-medium", "paligemma-3b",
        "minitron-8b", "glm4-9b", "starcoder2-7b", "moonshot-v1-16b-a3b"]
    b, s = 2, 64
    for arch in archs:
        cfg = get_smoke(arch)
        state = make_train_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(train_step_fn(cfg))
        batch = synthetic_batch(cfg, 0, b, s)
        t = time_fn(lambda st, ba: step(st, ba)[1]["loss"], state, batch)
        rows.append((f"train_smoke_{arch}", t * 1e6,
                     f"tok_per_s={b * s / t:,.0f}"))
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "benchmarks")
    from common import emit
    emit(run())
