"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, repeats=3, warmup=1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.time() - t0) / repeats


def emit(rows):
    """rows: list of (name, us_per_call, derived)."""
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
