"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, repeats=3, warmup=1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def interleaved_min(fns: dict, reps: int = 7) -> dict:
    """Drift-robust A/B timing: run the zero-arg callables in ``fns``
    round-robin, alternating which goes first each rep (the second call of
    a round rides warmed caches), and keep per-tag MINIMA.  Back-to-back
    blocks on a shared box fold clock drift and ordering bias straight
    into the ratio; this protocol cancels both.  Callers warm/compile each
    fn once before handing it in.  Returns {tag: best_seconds}."""
    best = {tag: float("inf") for tag in fns}
    order = list(fns)
    for r in range(reps):
        for tag in (order if r % 2 == 0 else order[::-1]):
            t0 = time.perf_counter()
            jax.block_until_ready(fns[tag]())
            best[tag] = min(best[tag], time.perf_counter() - t0)
    return best


def emit(rows):
    """rows: list of (name, us_per_call, derived[, interpret]).

    ``interpret`` (optional 4th element) tags rows whose timing comes from
    a Pallas interpret-mode execution: those numbers are CPU emulation of
    the kernel body, NOT hardware timings, and must never feed speedup
    claims (they are rendered as their own CSV column so downstream
    tooling can filter them).
    """
    for row in rows:
        name, us, derived = row[0], row[1], row[2]
        interp = row[3] if len(row) > 3 else False
        print(f"{name},{us:.1f},{derived},{'interpret' if interp else 'real'}")
