"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick mode
    PYTHONPATH=src python -m benchmarks.run --full     # paper-sized grids

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import os

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: convergence,comm,scaling,biot,"
                         "kernels,roofline,train,batch,solve")
    args = ap.parse_args()
    quick = not args.full

    import jax
    jax.config.update("jax_enable_x64", True)

    from common import emit
    jobs = {
        "convergence": "bench_convergence",
        "biot": "bench_biot_savart",
        "comm": "bench_comm",
        "scaling": "bench_scaling",
        "kernels": "bench_kernels",
        "train": "bench_train",
        "roofline": "bench_roofline",
        "batch": "bench_batch",
        "solve": "bench_solve",
    }
    only = args.only.split(",") if args.only else list(jobs)
    # the trailing column tags interpret-mode (CPU-emulated Pallas) timings,
    # which are excluded from every speedup claim -- see common.emit
    print("name,us_per_call,derived,timing")
    for key in only:
        mod = __import__(jobs[key])
        try:
            emit(mod.run(quick=quick))
        except Exception as e:  # keep the harness going
            emit([(f"{key}_ERROR", 0.0,
                   f"{type(e).__name__}: {e}")])


if __name__ == "__main__":
    main()
