"""Biot-Savart: recover the velocity field of a vortex tube (paper sec. V).

    PYTHONPATH=src python examples/biot_savart.py
"""
import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.bc import BCType
from repro.core.bc import DataLayout
from repro.core.biot_savart import BiotSavartSolver
from repro.core.green import GreenKind

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
from test_biot_savart import BCS, tube_fields, R  # noqa: E402

N = 48
f, u_ref = tube_fields(N)
solver = BiotSavartSolver((N, N, N), 1.0, BCS, layout=DataLayout.NODE,
                          green_kind=GreenKind.CHAT2, fd_order=0)
u = np.asarray(solver.solve(f))
err = np.max(np.abs(u - u_ref))
umax = np.abs(u_ref).max()
print(f"vortex tube R={R}: |u|_max={umax:.4f}  E_inf={err:.3e} "
      f"({100 * err / umax:.2f}% of peak)")
assert err < 0.02 * umax
print("OK")
