"""Quickstart: solve an unbounded Poisson problem in ~10 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.bc import BCType, DataLayout
from repro.core.green import GreenKind
from repro.core.solver import PoissonSolver

N, L = 64, 1.0
U = (BCType.UNB, BCType.UNB)

solver = PoissonSolver((N, N, N), L, (U, U, U), layout=DataLayout.NODE,
                       green_kind=GreenKind.HEJ4)

# a Gaussian bump as the right-hand side: the potential is analytic
from scipy.special import erf

a = 50.0
h = L / N
x, y, z = np.meshgrid(*([np.arange(N + 1) * h] * 3), indexing="ij")
r = np.sqrt((x - 0.5) ** 2 + (y - 0.5) ** 2 + (z - 0.5) ** 2)
rhs = np.exp(-a * r * r)

u = np.asarray(solver.solve(rhs))
print(f"solved {u.shape} grid: u in [{u.min():.5f}, {u.max():.5f}]")

# exact: u = -Q erf(sqrt(a) r) / (4 pi r),  Q = (pi/a)^{3/2}
Q = (np.pi / a) ** 1.5
rs = np.where(r > 1e-12, r, 1.0)
u_ref = -Q * erf(np.sqrt(a) * rs) / (4 * np.pi * rs)
u_ref = np.where(r > 1e-12, u_ref, -Q * np.sqrt(a) / (2 * np.pi ** 1.5))
err = np.max(np.abs(u - u_ref)) / np.abs(u_ref).max()
print(f"relative E_inf vs analytic Gaussian potential = {err:.2e}")
assert err < 2e-2
print("OK")
