"""Serve a small LM with batched requests: prefill + decode loop.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --prompt-len 64
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import transformer as tf

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-0.6b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=64)
ap.add_argument("--gen", type=int, default=32)
args = ap.parse_args()

cfg = get_smoke(args.arch)
params = tf.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                   (args.batch, args.prompt_len)), jnp.int32)
max_len = args.prompt_len + args.gen

print(f"prefill {args.batch}x{args.prompt_len} ...")
prefill = jax.jit(lambda p, t: tf.prefill(p, cfg, t, max_len=max_len))
logits, caches = prefill(params, prompts)
tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)

decode = jax.jit(lambda p, t, c, pos: tf.decode_step(p, cfg, t, c, pos))
out = [tok]
t0 = time.perf_counter()
for i in range(args.gen - 1):
    logits, caches = decode(params, tok, caches, args.prompt_len + i)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out.append(tok)
dt = time.perf_counter() - t0
gen = np.asarray(jnp.concatenate(out, axis=1))
print(f"generated {gen.shape} tokens, "
      f"{args.batch * (args.gen - 1) / dt:,.0f} tok/s (greedy)")
print("first request:", gen[0, :16], "...")
