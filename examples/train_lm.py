"""End-to-end driver: train a ~100M-parameter qwen3-style LM for a few
hundred steps with checkpoint/resume (CPU-sized batch; same code path the
production launcher uses).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.ckpt import checkpoint as ck
from repro.data.pipeline import synthetic_batch
from repro.training import optimizer as opt
from repro.training.train_step import make_train_state, train_step_fn

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

# qwen3-0.6b scaled to ~100M params: 12 layers, d=640, untied head off
base = get_config("qwen3-0.6b")
cfg = dataclasses.replace(base, n_layers=12, d_model=640, n_heads=10,
                          n_kv=5, d_ff=1920, vocab=32768, name="lm-100m")

state = make_train_state(jax.random.PRNGKey(0), cfg, lr=6e-4,
                         adam=opt.AdamWConfig(lr=6e-4,
                                              total_steps=args.steps))
n_params = sum(x.size for x in jax.tree.leaves(state.params))
print(f"model: {cfg.name}  params={n_params/1e6:.1f}M")

start = ck.latest_step(args.ckpt_dir) or 0
if start:
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        state)
    state = ck.restore(args.ckpt_dir, start, like)
    print(f"resumed from step {start}")

step_fn = jax.jit(train_step_fn(cfg))
t0 = time.perf_counter()
for step in range(start, args.steps):
    state, m = step_fn(state, synthetic_batch(cfg, step, args.batch,
                                              args.seq))
    if step % 20 == 0 or step == args.steps - 1:
        loss = float(m["loss"])
        tput = args.batch * args.seq * (step - start + 1) / \
            (time.perf_counter() - t0)
        print(f"step {step:4d}  loss {loss:.4f}  {tput:,.0f} tok/s")
    if (step + 1) % 100 == 0:
        ck.save(args.ckpt_dir, step + 1, state)
print("done")
