"""Sharding-aware checkpoint / restore (fault tolerance + elastic scaling).

Layout per step:
    <dir>/step_<k>.tmp/...  ->  atomic rename  ->  <dir>/step_<k>/
        manifest.json        tree structure, shapes, dtypes
        arr_<i>.npy          one file per leaf (full logical array)

Restore re-applies shardings for WHATEVER mesh the new job runs on: the
manifest stores logical shapes only, so a 512-chip checkpoint restores onto
256 or 1024 chips unchanged (elastic re-scale).  ``keep_last`` checkpoints
are retained; interrupted writes never corrupt a valid step (tmp+rename).

Every leaf entry also records a CRC32 content digest written at save time
and verified on restore (after the ``ckpt.leaf.<i>`` taint hook that models
storage rot), so a bit-flipped array raises :class:`CheckpointError` with
the offending leaf instead of silently resuming a corrupted campaign.
Shape/dtype validation alone cannot see this -- the flipped value is the
same size and finite.  Digests are optional in the manifest (checkpoints
written before this scheme still restore).

On a real multi-host cluster the same layout is written per-host with
process-local shards (jax.experimental.multihost_utils); this
single-controller implementation gathers to host memory, which is the
correct behaviour for the CPU validation environment.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib

import numpy as np
import jax

from repro.runtime import faults as _faults

SEP = "/"


class CheckpointError(RuntimeError):
    """A checkpoint failed validation on restore (manifest/array mismatch,
    truncated or missing leaf).  Deliberately NOT an AssertionError: the
    restart path catches it and falls back to the previous valid step."""

    def __init__(self, msg: str, *, path=None, leaf=None):
        super().__init__(msg)
        self.path = path
        self.leaf = leaf
        self.transient = False


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _digest(arr) -> str:
    """Content digest of one leaf (CRC32 over the raw bytes of a
    C-contiguous view; cheap relative to the npy write itself)."""
    a = np.ascontiguousarray(arr)
    return f"{zlib.crc32(a.tobytes()) & 0xffffffff:08x}"


def save(directory, step, tree, keep_last=3):
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    manifest = {"step": int(step), "treedef": str(treedef),
                "n_leaves": len(leaves), "leaves": []}
    for i, leaf in enumerate(leaves):
        # torn-write injection point: a ``torn_write`` spec firing here
        # kills the write mid-leaf, leaving a partial step_<k>.tmp that the
        # tmp+rename protocol keeps invisible to all_steps/restore
        _faults.fail_point(f"ckpt.leaf.{i}")
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype),
             "crc32": _digest(arr)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic commit
    _gc(directory, keep_last)
    return final


def _gc(directory, keep_last):
    steps = sorted(_listed_steps(directory))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"))


def _listed_steps(directory):
    """Step numbers with a committed dir + manifest (no array validation --
    gc must see damaged steps too, or it would never reclaim them)."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name,
                                            "manifest.json")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def _validate_step(path):
    """Full integrity check of one committed step dir against its manifest:
    every leaf present, loadable, and matching the recorded shape/dtype.
    ``np.load(mmap_mode="r")`` validates the npy header AND that the file
    holds all its bytes (truncation raises) without reading the data."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        entries = manifest["leaves"]
        if manifest["n_leaves"] != len(entries):
            raise CheckpointError(
                f"manifest inconsistent: n_leaves={manifest['n_leaves']} "
                f"but {len(entries)} leaf entries", path=path)
        for i, ent in enumerate(entries):
            arr = np.load(os.path.join(path, f"arr_{i}.npy"), mmap_mode="r")
            if tuple(arr.shape) != tuple(ent["shape"]) or \
                    str(arr.dtype) != ent["dtype"]:
                raise CheckpointError(
                    f"leaf {i} is {arr.shape}/{arr.dtype} on disk but the "
                    f"manifest records {tuple(ent['shape'])}/{ent['dtype']}",
                    path=path, leaf=i)
        return manifest
    except CheckpointError:
        raise
    except Exception as e:   # missing/truncated file, unreadable manifest
        raise CheckpointError(
            f"checkpoint at {path} is damaged: {e}", path=path) from e


def step_valid(directory, step) -> bool:
    try:
        _validate_step(os.path.join(directory, f"step_{step}"))
        return True
    except CheckpointError:
        return False


def all_steps(directory):
    """Steps that would actually restore: committed AND integrity-valid.
    A step whose arrays are truncated or missing (torn write past the
    rename, disk rot) is skipped, so restart falls back to the previous
    valid step."""
    return [s for s in _listed_steps(directory) if step_valid(directory, s)]


def latest_step(directory):
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory, step, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; optionally re-shard with
    a matching tree of NamedSharding (elastic restore onto any mesh).

    The manifest is validated against both the on-disk arrays and
    ``like_tree`` (leaf count, per-leaf shape) before anything is loaded;
    mismatches raise :class:`CheckpointError` with the offending leaf."""
    path = os.path.join(directory, f"step_{step}")
    manifest = _validate_step(path)
    leaves, treedef = _flatten(like_tree)
    if manifest["n_leaves"] != len(leaves):
        raise CheckpointError(
            f"tree structure changed: checkpoint has "
            f"{manifest['n_leaves']} leaves, restore target has "
            f"{len(leaves)}", path=path)
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    for i, (leaf, shd) in enumerate(zip(leaves, shard_leaves)):
        ent = manifest["leaves"][i]
        if tuple(ent["shape"]) != tuple(leaf.shape):
            raise CheckpointError(
                f"leaf {i}: checkpoint shape {tuple(ent['shape'])} != "
                f"restore target shape {tuple(leaf.shape)}",
                path=path, leaf=i)
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        # storage-rot injection point (host-side: this data never enters a
        # trace); the digest check below is what must catch it
        arr = _faults.taint_host(f"ckpt.leaf.{i}", arr)
        want = ent.get("crc32")
        if want is not None and _digest(arr) != want:
            raise CheckpointError(
                f"leaf {i} content digest mismatch (got {_digest(arr)}, "
                f"manifest records {want}): checkpoint bytes rotted "
                f"between save and restore", path=path, leaf=i)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
