"""Sharding-aware checkpoint / restore (fault tolerance + elastic scaling).

Layout per step:
    <dir>/step_<k>.tmp/...  ->  atomic rename  ->  <dir>/step_<k>/
        manifest.json        tree structure, shapes, dtypes
        arr_<i>.npy          one file per leaf (full logical array)

Restore re-applies shardings for WHATEVER mesh the new job runs on: the
manifest stores logical shapes only, so a 512-chip checkpoint restores onto
256 or 1024 chips unchanged (elastic re-scale).  ``keep_last`` checkpoints
are retained; interrupted writes never corrupt a valid step (tmp+rename).

On a real multi-host cluster the same layout is written per-host with
process-local shards (jax.experimental.multihost_utils); this
single-controller implementation gathers to host memory, which is the
correct behaviour for the CPU validation environment.
"""
from __future__ import annotations

import json
import os
import shutil

import numpy as np
import jax

SEP = "/"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory, step, tree, keep_last=3):
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    manifest = {"step": int(step), "treedef": str(treedef),
                "n_leaves": len(leaves), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic commit
    _gc(directory, keep_last)
    return final


def _gc(directory, keep_last):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"))


def all_steps(directory):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name,
                                            "manifest.json")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory):
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory, step, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; optionally re-shard with
    a matching tree of NamedSharding (elastic restore onto any mesh)."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like_tree)
    assert manifest["n_leaves"] == len(leaves), "tree structure changed"
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    for i, (leaf, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        assert tuple(arr.shape) == tuple(leaf.shape), \
            (i, arr.shape, leaf.shape)
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
