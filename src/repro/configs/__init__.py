"""Architecture registry: ``--arch <id>`` -> config, shapes, applicability."""
from __future__ import annotations

from dataclasses import dataclass
from importlib import import_module

_MODULES = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "minitron-8b": "minitron_8b",
    "glm4-9b": "glm4_9b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen3-0.6b": "qwen3_0_6b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-2.7b": "mamba2_2_7b",
    "whisper-medium": "whisper_medium",
    "paligemma-3b": "paligemma_3b",
    "flups-poisson": "flups_poisson",
}

LM_ARCHS = tuple(a for a in _MODULES if a != "flups-poisson")
ALL_ARCHS = tuple(_MODULES)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str):
    mod = import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke(arch: str):
    mod = import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE


def arch_shapes(arch: str):
    """The shape cells defined for an architecture.

    ``long_500k`` needs sub-quadratic sequence mixing: run for ssm/hybrid
    only (skip noted in DESIGN.md section Arch-applicability).  The
    flups-poisson arch uses its own grid, not the LM shapes.
    """
    if arch == "flups-poisson":
        return ()
    cfg = get_config(arch)
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return tuple(SHAPES[n] for n in names)
