"""flups_poisson: the paper's own workload as a selectable architecture --
a distributed unbounded Poisson solve on the production mesh (the FFT side
of the framework, run through the same dry-run/roofline machinery)."""
from dataclasses import dataclass

from repro.core.bc import BCType, DataLayout
from repro.core.green import GreenKind

ARCH = "flups-poisson"


@dataclass(frozen=True)
class PoissonArchConfig:
    name: str
    n: int                      # cells per direction (global)
    layout: DataLayout
    bcs: tuple
    green: str
    batch: int = 1              # fields solved per step (data parallel)
    engine: str = "xla"         # transform engine: "xla" | "pallas"
    # Hockney doubling placement for the unbounded dirs: "deferred" (pruned
    # transforms + valid-extent topology switches, DESIGN.md #8) or
    # "upfront" (dense textbook baseline kept for A/B runs)
    doubling: str = "deferred"
    # data-layout policy (DESIGN.md #9): "scheduled" (plan-time layout
    # schedule; relayouts folded into the topology-switch unpack, zero
    # standalone transposes between stages) or "baseline" (per-direction
    # moveaxis round trips, the A/B reference)
    relayout: str = "scheduled"
    # topology-switch communication (DESIGN.md #2), applied whenever the
    # launcher passes the stock default strategy:
    # "a2a" | "pipelined" | "fused" | "overlap" | "auto" (plan-time tuner)
    comm: str = "a2a"
    comm_chunks: int = 2        # pipelined/overlap granularity (n_batch)
    # autotuner cache knobs (comm="auto"): winners are cached in-process per
    # (shape, bcs, layout, mesh) key; a non-empty path (or $REPRO_COMM_CACHE)
    # persists them as JSON so later processes skip the timing sweep
    comm_autotune_cache: str = ""
    comm_autotune_max_chunks: int = 4   # sweep n_chunks in {2, 4, ...}
    # comm="auto" candidate policy (DESIGN.md #12): "guided" ranks the
    # candidate space with the analytic cost model and wall-clock times
    # only the shortlisted frontier (~1/6 of the space); "brute" sweeps
    # every candidate (the oracle reference the guided mode is gated on)
    comm_autotune_search: str = "guided"
    # per-candidate wall-clock budget for the comm="auto" sweep, seconds
    # (0 = unlimited, or $REPRO_COMM_BUDGET); one pathological candidate
    # must never stall plan construction -- it is skipped and recorded in
    # the solver's autotune census (DESIGN.md #10)
    comm_autotune_budget_s: float = 0.0
    # numerical health guard armed on every solve (DESIGN.md #10):
    # "" (off) | "nan" (finiteness) | "residual" (finiteness + FD residual)
    # | "abft" (per-stage checksum invariants with inline selective
    # recompute and wire/compute attribution -- DESIGN.md #13; overhead
    # gated <=5% in CI via bench_solve --check)
    verify: str = ""
    verify_rtol: float = 0.5
    # ABFT mismatch tolerance; 0.0 = auto per dtype (runtime.abft.tol_for)
    abft_rtol: float = 0.0


U = (BCType.UNB, BCType.UNB)

CONFIG = PoissonArchConfig(
    # 2048^3 global cells: ~2.1 GB/chip on the doubled spectral domain at
    # 256 chips -- a production-plausible per-chip load (paper: 96^3/core)
    name=ARCH, n=2048, layout=DataLayout.NODE, bcs=(U, U, U),
    green=GreenKind.CHAT2, batch=2,
)

SMOKE = PoissonArchConfig(
    name=ARCH + "-smoke", n=16, layout=DataLayout.NODE, bcs=(U, U, U),
    green=GreenKind.CHAT2, batch=1,
)
