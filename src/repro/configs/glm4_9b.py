"""glm4-9b: dense 40L, GQA kv=2, partial RoPE (half dims). [hf:THUDM/glm-4-9b]"""
from repro.models.common import ModelConfig

ARCH = "glm4-9b"

CONFIG = ModelConfig(
    name=ARCH, family="dense", n_layers=40, d_model=4096, n_heads=32,
    n_kv=2, d_head=128, d_ff=13696, vocab=151552, act="swiglu",
    rope_fraction=0.5,
)

SMOKE = ModelConfig(
    name=ARCH + "-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=512, act="swiglu",
    rope_fraction=0.5,
)
