"""mamba2-2.7b: attention-free SSD (state-space duality), 64L, state 128.
[arXiv:2405.21060]"""
from repro.models.common import ModelConfig, SSMConfig

ARCH = "mamba2-2.7b"

CONFIG = ModelConfig(
    name=ARCH, family="ssm", n_layers=64, d_model=2560, n_heads=1,
    n_kv=1, d_head=1, d_ff=0, vocab=50280, tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
)

SMOKE = ModelConfig(
    name=ARCH + "-smoke", family="ssm", n_layers=2, d_model=64, n_heads=1,
    n_kv=1, d_head=1, d_ff=0, vocab=512, tie_embeddings=True,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=8),
)
