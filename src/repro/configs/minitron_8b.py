"""minitron-8b: dense 32L, pruned Nemotron (squared-ReLU MLP, GQA kv=8).
[arXiv:2407.14679]"""
from repro.models.common import ModelConfig

ARCH = "minitron-8b"

CONFIG = ModelConfig(
    name=ARCH, family="dense", n_layers=32, d_model=4096, n_heads=32,
    n_kv=8, d_head=128, d_ff=16384, vocab=256000, act="relu2",
)

SMOKE = ModelConfig(
    name=ARCH + "-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=512, act="relu2",
)
