"""moonshot-v1-16b-a3b (Moonlight-16B-A3B): 48L MoE, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B]"""
from repro.models.common import ModelConfig, MoEConfig

ARCH = "moonshot-v1-16b-a3b"

CONFIG = ModelConfig(
    name=ARCH, family="moe", n_layers=48, d_model=2048, n_heads=16,
    n_kv=16, d_head=128, d_ff=1408, vocab=163840, act="swiglu",
    rope_theta=50_000.0, moe=MoEConfig(n_experts=64, top_k=6),
)

SMOKE = ModelConfig(
    name=ARCH + "-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv=4, d_head=16, d_ff=96, vocab=512, act="swiglu",
    moe=MoEConfig(n_experts=8, top_k=2),
)
