"""paligemma-3b: SigLIP frontend STUB (precomputed patch embeddings) +
gemma-2b decoder, prefix-LM attention over the image tokens.
[arXiv:2407.07726]"""
from repro.models.common import ModelConfig

ARCH = "paligemma-3b"

CONFIG = ModelConfig(
    name=ARCH, family="vlm", n_layers=18, d_model=2048, n_heads=8,
    n_kv=1, d_head=256, d_ff=16384, vocab=257216, act="geglu",
    tie_embeddings=True, scale_embed=True, n_frontend_tokens=256,
)

SMOKE = ModelConfig(
    name=ARCH + "-smoke", family="vlm", n_layers=2, d_model=64, n_heads=4,
    n_kv=1, d_head=16, d_ff=128, vocab=512, act="geglu",
    tie_embeddings=True, scale_embed=True, n_frontend_tokens=8,
)
