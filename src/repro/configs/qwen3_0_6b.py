"""qwen3-0.6b: dense 28L d1024, qk-norm, GQA kv=8, tied embeddings.
[hf:Qwen/Qwen3-0.6B]"""
from repro.models.common import ModelConfig

ARCH = "qwen3-0.6b"

CONFIG = ModelConfig(
    name=ARCH, family="dense", n_layers=28, d_model=1024, n_heads=16,
    n_kv=8, d_head=128, d_ff=3072, vocab=151936, act="swiglu",
    qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name=ARCH + "-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=512, act="swiglu",
    qk_norm=True, tie_embeddings=True,
)
