"""qwen3-moe-235b-a22b: 94L MoE, 128 experts top-8, GQA kv=4, qk-norm.
[hf:Qwen/Qwen3-235B-A22B family]"""
from repro.models.common import ModelConfig, MoEConfig

ARCH = "qwen3-moe-235b-a22b"

CONFIG = ModelConfig(
    name=ARCH, family="moe", n_layers=94, d_model=4096, n_heads=64,
    n_kv=4, d_head=128, d_ff=1536, vocab=151936, act="swiglu",
    qk_norm=True, rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8),
)

SMOKE = ModelConfig(
    name=ARCH + "-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
    n_kv=2, d_head=16, d_ff=96, vocab=512, act="swiglu", qk_norm=True,
    moe=MoEConfig(n_experts=8, top_k=2),
)
