"""recurrentgemma-9b: hybrid RG-LRU + local attention, pattern
(rec, rec, attn), MQA kv=1, 2k window. [arXiv:2402.19427]"""
from repro.models.common import ModelConfig, HybridConfig

ARCH = "recurrentgemma-9b"

CONFIG = ModelConfig(
    name=ARCH, family="hybrid", n_layers=38, d_model=4096, n_heads=16,
    n_kv=1, d_head=256, d_ff=12288, vocab=256000, act="geglu",
    window=2048, tie_embeddings=True, scale_embed=True,
    hybrid=HybridConfig(d_rnn=4096, conv_width=4, window=2048,
                        pattern=("rec", "rec", "attn")),
)

SMOKE = ModelConfig(
    name=ARCH + "-smoke", family="hybrid", n_layers=3, d_model=64,
    n_heads=4, n_kv=1, d_head=16, d_ff=128, vocab=512, act="geglu",
    window=16, tie_embeddings=True, scale_embed=True,
    hybrid=HybridConfig(d_rnn=64, conv_width=4, window=16,
                        pattern=("rec", "rec", "attn")),
)
