"""starcoder2-7b: dense 36H/4kv, LayerNorm, GELU MLP, 4k sliding window.
[arXiv:2402.19173]"""
from repro.models.common import ModelConfig

ARCH = "starcoder2-7b"

CONFIG = ModelConfig(
    name=ARCH, family="dense", n_layers=32, d_model=4608, n_heads=36,
    n_kv=4, d_head=128, d_ff=18432, vocab=49152, act="gelu", norm="layer",
    window=4096, rope_theta=1e5, tie_embeddings=True, norm_eps=1e-5,
)

SMOKE = ModelConfig(
    name=ARCH + "-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=512, act="gelu",
    norm="layer", window=16, tie_embeddings=True, norm_eps=1e-5,
)
