"""whisper-medium: 24L encoder + 24L decoder, MHA, conv frontend STUB
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.models.common import ModelConfig

ARCH = "whisper-medium"

CONFIG = ModelConfig(
    name=ARCH, family="encdec", n_layers=24, n_enc_layers=24, d_model=1024,
    n_heads=16, n_kv=16, d_head=64, d_ff=4096, vocab=51865, act="gelu",
    norm="layer", tie_embeddings=True, n_frontend_tokens=1500,
    norm_eps=1e-5,
)

SMOKE = ModelConfig(
    name=ARCH + "-smoke", family="encdec", n_layers=2, n_enc_layers=2,
    d_model=64, n_heads=4, n_kv=4, d_head=16, d_ff=128, vocab=512,
    act="gelu", norm="layer", tie_embeddings=True, n_frontend_tokens=8,
    norm_eps=1e-5,
)
