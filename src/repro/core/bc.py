"""Boundary conditions, data layouts, and the BC -> transform-kind planning.

This encodes Table I of the paper plus the periodic / unbounded cases:

    node-centered:  odd-odd -> DST-I,  odd-even -> DST-III,
                    even-odd -> DCT-III, even-even -> DCT-I
    cell-centered:  odd-odd -> DST-II, odd-even -> DST-IV,
                    even-odd -> DCT-IV, even-even -> DCT-II

Unbounded / semi-unbounded directions use the Hockney--Eastwood domain
doubling (section II-C): the FFT size doubles and the transform becomes a
DFT (fully unbounded) or the DCT/DST imposing the symmetry at the bounded
end (semi-unbounded).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass


class BCType(enum.Enum):
    EVEN = "even"
    ODD = "odd"
    PER = "periodic"
    UNB = "unbounded"


class DataLayout(enum.Enum):
    CELL = "cell"  # x_j = (j + 1/2) h, j in [0, N-1]
    NODE = "node"  # x_j = j h,         j in [0, N]


class TransformKind(enum.Enum):
    DFT_R2C = "dft_r2c"
    DFT_C2C = "dft_c2c"
    DCT1 = "dct1"
    DCT2 = "dct2"
    DCT3 = "dct3"
    DCT4 = "dct4"
    DST1 = "dst1"
    DST2 = "dst2"
    DST3 = "dst3"
    DST4 = "dst4"


# (left BC, right BC) -> transform kind, per data layout (paper Table I).
_TABLE_NODE = {
    (BCType.ODD, BCType.ODD): TransformKind.DST1,
    (BCType.ODD, BCType.EVEN): TransformKind.DST3,
    (BCType.EVEN, BCType.ODD): TransformKind.DCT3,
    (BCType.EVEN, BCType.EVEN): TransformKind.DCT1,
}
_TABLE_CELL = {
    (BCType.ODD, BCType.ODD): TransformKind.DST2,
    (BCType.ODD, BCType.EVEN): TransformKind.DST4,
    (BCType.EVEN, BCType.ODD): TransformKind.DCT4,
    (BCType.EVEN, BCType.EVEN): TransformKind.DCT2,
}

# Backward (inverse) kind for each forward r2r kind.
INVERSE_KIND = {
    TransformKind.DCT1: TransformKind.DCT1,
    TransformKind.DCT2: TransformKind.DCT3,
    TransformKind.DCT3: TransformKind.DCT2,
    TransformKind.DCT4: TransformKind.DCT4,
    TransformKind.DST1: TransformKind.DST1,
    TransformKind.DST2: TransformKind.DST3,
    TransformKind.DST3: TransformKind.DST2,
    TransformKind.DST4: TransformKind.DST4,
    TransformKind.DFT_R2C: TransformKind.DFT_R2C,
    TransformKind.DFT_C2C: TransformKind.DFT_C2C,
}


@dataclass(frozen=True)
class DirBC:
    """Boundary condition pair for one direction."""

    left: BCType
    right: BCType

    @property
    def is_periodic(self) -> bool:
        return self.left == BCType.PER or self.right == BCType.PER

    @property
    def is_unbounded(self) -> bool:
        return self.left == BCType.UNB and self.right == BCType.UNB

    @property
    def is_semi_unbounded(self) -> bool:
        return (self.left == BCType.UNB) != (self.right == BCType.UNB)

    @property
    def is_spectral(self) -> bool:
        """True when the direction needs no domain doubling."""
        return not (self.is_unbounded or self.is_semi_unbounded)

    def validate(self) -> None:
        if (self.left == BCType.PER) != (self.right == BCType.PER):
            raise ValueError("periodic BC must be imposed on both ends")


def r2r_kind(bc: DirBC, layout: DataLayout) -> TransformKind:
    """Transform kind for a fully symmetric (even/odd) direction."""
    table = _TABLE_NODE if layout == DataLayout.NODE else _TABLE_CELL
    return table[(bc.left, bc.right)]


def semi_unbounded_kind(bc: DirBC, layout: DataLayout) -> TransformKind:
    """Transform for a semi-unbounded direction on the *doubled* domain.

    The symmetry at the bounded end is imposed by the real-to-real
    transform; the unbounded end is handled by zero padding.  Following
    flups we always flip the data so the symmetric end sits at the left
    (j = 0); the doubled domain then behaves like a (sym, even) pair as
    the zero-padded far end is even-extendable without error.
    """
    sym = bc.left if bc.left != BCType.UNB else bc.right
    pair = (sym, BCType.EVEN)
    table = _TABLE_NODE if layout == DataLayout.NODE else _TABLE_CELL
    return table[pair]


def count_unbounded(bcs) -> int:
    return sum(1 for b in bcs if b.is_unbounded or b.is_semi_unbounded)
