"""Biot-Savart solver:  lap(u) = curl(f)  (paper section V).

Forward-transform the three components of ``f`` (each with its own BCs),
evaluate the curl in spectral space (DCT<->DST swaps + i*omega factors),
multiply by the Green's function assembled on the *velocity* plans, and
transform backward with the velocity plans.

The velocity BCs are derived from the vorticity BCs by the swap algebra:
component c of ``curl f`` differentiates f_b along a (cyclic), flipping
even<->odd along the differentiated direction only.  Both curl terms must
land in the same basis -- asserted at plan time; this is the compatibility
condition on the user-provided vorticity BCs.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .bc import BCType, DirBC, DataLayout
from . import green as gr
from .engine import as_engine, build_schedule
from .solver import make_plan, build_green, _fwd_1d, _bwd_1d
from .spectral import apply_derivative, swap_bc

__all__ = ["BiotSavartSolver"]

_CYCLIC = ((0, 1, 2), (1, 2, 0), (2, 0, 1))  # (c, a, b): u_c = d_a f_b - d_b f_a


def _swap_dir(bcs_dir: DirBC) -> DirBC:
    return DirBC(swap_bc(bcs_dir.left), swap_bc(bcs_dir.right))


class BiotSavartSolver:
    """u = solve(f): lap(u) = curl(f) with per-component BCs.

    ``bcs``: (3, 3) nested sequence -- bcs[c][d] is the (left, right) BC
    pair of vorticity component c along direction d.
    """

    def __init__(self, shape, L, bcs, layout=DataLayout.CELL,
                 green_kind=gr.GreenKind.CHAT2, fd_order: int = 0,
                 eps_factor: float = 2.0, engine="xla"):
        self.fd_order = fd_order
        self.engine = as_engine(engine)
        bcs = [[DirBC(*b) if not isinstance(b, DirBC) else b for b in row]
               for row in bcs]
        self.fplans = [make_plan(shape, L, bcs[c], layout, green_kind,
                                 eps_factor) for c in range(3)]
        # velocity BCs from term d_a f_b; cross-checked against d_b f_a
        self.uplans = []
        for c, a, b in _CYCLIC:
            bc1 = [_swap_dir(bcs[b][d]) if d == a else bcs[b][d]
                   for d in range(3)]
            bc2 = [_swap_dir(bcs[a][d]) if d == b else bcs[a][d]
                   for d in range(3)]
            if bc1 != bc2:
                raise ValueError(
                    f"incompatible vorticity BCs for velocity component {c}: "
                    f"{bc1} vs {bc2}")
            self.uplans.append(make_plan(shape, L, bc1, layout, green_kind,
                                         eps_factor))
        self.greens = [build_green(p) for p in self.uplans]
        self.fscheds = [build_schedule(p, self.engine) for p in self.fplans]
        self.uscheds = [build_schedule(p, self.engine) for p in self.uplans]
        # uniform per-component plans (e.g. the fully-unbounded vortex
        # workload): the 3 components become ONE batched solve -- a single
        # forward/backward transform pipeline with batch axis 3 and one
        # fused Green multiply, instead of 3 sequential component solves
        self.batched = (all(p == self.fplans[0] for p in self.fplans)
                        and all(p == self.uplans[0] for p in self.uplans))
        self._solve = jax.jit(self._solve_impl_batched if self.batched
                              else self._solve_impl)

    @property
    def input_shape(self):
        return (3,) + self.fplans[0].input_shape

    def _fwd(self, f, plan, sched):
        y = f
        for d in plan.order:
            y = _fwd_1d(y, plan.dirs[d], sched)
        return y

    def _bwd(self, y, plan, sched, dtype):
        for d in reversed(plan.order):
            y = _bwd_1d(y, plan.dirs[d], sched)
        if jnp.iscomplexobj(y):
            y = y.real
        return y.astype(dtype)

    def _solve_impl(self, f):
        fh = [self._fwd(f[c], self.fplans[c], self.fscheds[c])
              for c in range(3)]
        out = []
        for c, a, b in _CYCLIC:
            up = self.uplans[c]
            t1 = apply_derivative(fh[b], self.fplans[b].dirs[a],
                                  up.dirs[a], self.fd_order)
            t2 = apply_derivative(fh[a], self.fplans[a].dirs[b],
                                  up.dirs[b], self.fd_order)
            uhat = (t1 - t2) * jnp.asarray(self.greens[c]).astype(
                t1.dtype if not jnp.iscomplexobj(t1) else
                jnp.asarray(self.greens[c]).dtype)
            out.append(self._bwd(uhat, up, self.uscheds[c], f.dtype))
        return jnp.stack(out)

    def _solve_impl_batched(self, f):
        """Uniform-plan path: the component axis is the batch axis of one
        fused forward -> curl -> Green -> backward pipeline."""
        sched = self.fscheds[0]
        fh = self._fwd(f, self.fplans[0], sched)        # (3, *spectral)
        terms = []
        for c, a, b in _CYCLIC:
            t1 = apply_derivative(fh[b], self.fplans[0].dirs[a],
                                  self.uplans[0].dirs[a], self.fd_order)
            t2 = apply_derivative(fh[a], self.fplans[0].dirs[b],
                                  self.uplans[0].dirs[b], self.fd_order)
            terms.append(t1 - t2)
        uhat = self.uscheds[0].green_multiply(
            jnp.stack(terms), jnp.asarray(self.greens[0]))
        return self._bwd(uhat, self.uplans[0], self.uscheds[0], f.dtype)

    def solve(self, f):
        f = jnp.asarray(f)
        assert f.shape == self.input_shape, (f.shape, self.input_shape)
        return self._solve(f)
