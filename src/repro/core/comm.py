"""Topology-switch communication strategies (paper section III, TPU-native).

A topology switch moves the pencil from one active direction to the next:
the local block splits its (previously full) active axis across the ranks of
ONE mesh axis and gathers the next axis -- flups' sub-communicator scoping
maps 1:1 onto named mesh axes.

Three strategies, adapted from the paper's MPI designs (see DESIGN.md #2):

* ``a2a``      -- one ``lax.all_to_all`` on the whole block, followed by an
                  explicit contiguous materialization (the analogue of the
                  pack/unpack into dedicated communication buffers around
                  ``MPI_Ialltoallv``).  Simple, fully synchronous.
* ``pipelined``-- the paper's ``nb``: the block is cut into ``n_chunks``
                  along an uninvolved axis and each chunk is exchanged by its
                  own all-to-all; chunk k's local shuffle is independent of
                  chunk k+1's collective, exposing compute/comm overlap to
                  the scheduler (the role of n_batch / MPI_Testsome).
* ``fused``    -- the paper's ``isr``: no explicit pre/post packing at all;
                  the all-to-all output keeps its natural (strided) layout
                  and downstream ops fold the reorder into their own
                  indexing, i.e. the MPI_Datatype role is played by XLA
                  layout assignment.

All strategies are numerically identical (asserted in tests); they differ
in the HLO they emit, which is what the §Perf iteration studies.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

STRATEGIES = ("a2a", "pipelined", "fused")


@dataclass(frozen=True)
class CommConfig:
    strategy: str = "a2a"
    n_chunks: int = 2          # pipelined granularity (the paper's n_batch)

    def __post_init__(self):
        assert self.strategy in STRATEGIES, self.strategy


def _uninvolved_axis(ndim: int, split_axis: int, concat_axis: int) -> int:
    for ax in range(ndim - 1, -1, -1):
        if ax not in (split_axis, concat_axis):
            return ax
    raise ValueError("need >= 3 axes for the pipelined strategy")


def topology_switch(x, axis_name, split_axis: int, concat_axis: int,
                    cfg: CommConfig):
    """Distributed transpose: split ``split_axis`` over ``axis_name`` ranks,
    gather ``concat_axis``.  Must run inside shard_map."""
    if cfg.strategy == "pipelined" and cfg.n_chunks > 1:
        ax = _uninvolved_axis(x.ndim, split_axis, concat_axis)
        if x.shape[ax] % cfg.n_chunks == 0:
            chunks = jnp.split(x, cfg.n_chunks, axis=ax)
            outs = [
                lax.all_to_all(c, axis_name, split_axis, concat_axis,
                               tiled=True)
                for c in chunks
            ]
            return jnp.concatenate(outs, axis=ax)
        # fall through to a single collective when the axis does not divide
    y = lax.all_to_all(x, axis_name, split_axis, concat_axis, tiled=True)
    if cfg.strategy == "a2a":
        # explicit pack/unpack materialization: force a contiguous copy so
        # the collective is surrounded by dedicated buffer ops (flups a2a)
        try:
            y = lax.optimization_barrier(y)
        except NotImplementedError:
            # older jax has no batching rule for optimization_barrier (hit
            # under the multi-pod vmap); the barrier is a scheduling hint
            # only, so dropping it preserves semantics
            pass
    return y


def all_reduce_mean(x, axis_name):
    return lax.pmean(x, axis_name)
