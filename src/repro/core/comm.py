"""Topology-switch communication strategies (paper section III, TPU-native).

A topology switch moves the pencil from one active direction to the next:
the local block splits its (previously full) active axis across the ranks of
ONE mesh axis and gathers the next axis -- flups' sub-communicator scoping
maps 1:1 onto named mesh axes.

Each strategy is a ``CommStrategy`` class (see DESIGN.md #2); all are
numerically identical (asserted in tests) and differ only in the HLO they
emit, which is what the §Perf iteration studies:

* ``a2a``       -- one ``lax.all_to_all`` on the whole block, followed by an
                   explicit contiguous materialization (the analogue of the
                   pack/unpack into dedicated communication buffers around
                   ``MPI_Ialltoallv``).  Simple, fully synchronous.
* ``pipelined`` -- the paper's ``nb``: the block is cut into ``n_chunks``
                   along an uninvolved axis and each chunk is exchanged by
                   its own all-to-all; chunk k's local shuffle is independent
                   of chunk k+1's collective, exposing comm/comm overlap to
                   the scheduler (the role of n_batch / MPI_Testsome).  The
                   neighboring transforms stay monolithic.
* ``fused``     -- the paper's ``isr``: no explicit pre/post packing at all;
                   the all-to-all output keeps its natural (strided) layout
                   and downstream ops fold the reorder into their own
                   indexing, i.e. the MPI_Datatype role is played by XLA
                   layout assignment.
* ``overlap``   -- software-pipelined switch+transform stage: the collective
                   for chunk k+1 is issued BEFORE the next direction's 1-D
                   transform of chunk k, so transform compute genuinely
                   overlaps collective latency (flups' non-blocking variants
                   overlapping shuffle with MPI progress).  Requires the
                   caller to hand the per-chunk continuation to ``stage``
                   (the ``TransformSchedule.fwd_chunk``/``bwd_chunk`` API).

On top, ``autotune_comm`` is the analogue of flups' switchsort self-tuning:
it times candidate (strategy, n_chunks) pairs for the actual plan shapes and
mesh and caches the winner per plan/mesh key (in-memory, plus an optional
JSON file given by ``cache_path`` / $REPRO_COMM_CACHE).
"""
from __future__ import annotations

import json
import os
import threading
import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from repro.runtime import faults as _faults

STRATEGIES = ("a2a", "pipelined", "fused", "overlap")

__all__ = [
    "STRATEGIES", "FOLDS", "CHUNK_AXES", "CACHE_SCHEMA",
    "CommConfig", "CommStrategy", "as_comm",
    "make_strategy", "cfg_label", "label_to_cfg",
    "topology_switch", "pad_axis", "crop_axis",
    "autotune_comm", "autotune_candidates",
    "cache_load_entries", "cache_store_entry",
    "clear_autotune_cache", "all_reduce_mean", "reset_warn_once",
]


FOLDS = ("pack", "unpack")
# chunk-axis policy of the chunked strategies: "auto" honors the caller's
# preferred free axis (the in-block multi-RHS batch) when it divides
# n_chunks, "grid" always cuts the uninvolved grid axis -- a searchable
# trade (batch chunking never pads; grid chunking keeps per-chunk rows
# contiguous for the neighboring transforms)
CHUNK_AXES = ("auto", "grid")


@dataclass(frozen=True)
class CommConfig:
    strategy: str = "a2a"
    n_chunks: int = 2          # pipelined/overlap granularity (paper n_batch)
    # which side of the collective the layout-scheduled relayout is folded
    # into (DESIGN.md #9): "pack" permutes BEFORE the all-to-all (the
    # collective then splits a contiguous major axis), "unpack" permutes
    # each switched block AFTER it (the collective sees the transform's
    # minor-most layout).  Which is faster is shape- and backend-dependent
    # -- exactly the flups switchsort situation -- so ``autotune_comm``
    # sweeps both for layout-scheduled plans.  Ignored by the baseline
    # (moveaxis) pipelines and by ``permute=None`` call sites.
    fold: str = "pack"
    chunk_axis: str = "auto"   # see CHUNK_AXES

    def __post_init__(self):
        assert self.strategy in STRATEGIES, self.strategy
        assert self.n_chunks >= 1, self.n_chunks
        assert self.fold in FOLDS, self.fold
        assert self.chunk_axis in CHUNK_AXES, self.chunk_axis


def cfg_label(cfg: CommConfig) -> str:
    """Canonical candidate label: ``strategy:n_chunks`` plus non-default
    knobs (``:unpack``, ``:ca=grid``).  Stable across releases -- labels
    are cache-key material (the candidate grid is part of the autotune
    identity) and census/diagnostic keys."""
    lbl = f"{cfg.strategy}:{cfg.n_chunks}"
    if cfg.fold != "pack":
        lbl += f":{cfg.fold}"
    if cfg.chunk_axis != "auto":
        lbl += f":ca={cfg.chunk_axis}"
    return lbl


def label_to_cfg(label: str) -> CommConfig:
    parts = label.split(":")
    fold, ca = "pack", "auto"
    for p in parts[2:]:
        if p.startswith("ca="):
            ca = p[3:]
        elif p in FOLDS:
            fold = p
    return CommConfig(parts[0], int(parts[1]), fold, ca)


def as_comm(comm) -> CommConfig:
    """Accept ``CommConfig`` / strategy name / None (``"auto"`` is resolved
    by the solver via ``autotune_comm`` before this point)."""
    if comm is None:
        return CommConfig()
    if isinstance(comm, CommConfig):
        return comm
    return CommConfig(strategy=str(comm))


# ---------------------------------------------------------------------------
# chunking helpers
# ---------------------------------------------------------------------------

def _uninvolved_axis(ndim: int, split_axis: int, concat_axis: int) -> int:
    for ax in range(ndim - 1, -1, -1):
        if ax not in (split_axis, concat_axis):
            return ax
    raise ValueError("need >= 3 axes for a chunked strategy")


_WARNED: set = set()


def _warn_once(msg: str):
    if msg not in _WARNED:
        _WARNED.add(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=4)


def reset_warn_once():
    """Re-arm every one-shot diagnostic.  The module-global ``_WARNED`` set
    otherwise never resets, so a long-lived serve process would suppress
    per-plan warnings forever (and tests would pass/fail by execution
    order).  Wired into ``solver.clear_solver_cache`` and the test-session
    fixtures; servers may also call it on a stats epoch."""
    _WARNED.clear()


def _split_chunks(x, ax: int, n: int):
    """Cut ``x`` into ``n`` equal chunks along ``ax``, zero-padding the axis
    to the next multiple when it does not divide (warned once per shape --
    the seed silently fell back to a single collective here)."""
    ln = x.shape[ax]
    if ln % n:
        target = -(-ln // n) * n
        _warn_once(
            f"comm: chunk axis {ax} (length {ln}) does not divide into "
            f"{n} chunks; zero-padding to {target} (cropped after the "
            f"switch)")
        x = pad_axis(x, ax, target)
    return jnp.split(x, n, axis=ax), ln


def pad_axis(x, ax: int, target: int):
    """Zero-pad ``ax`` up to ``target`` (no-op when already there)."""
    if x.shape[ax] == target:
        return x
    pad = [(0, 0)] * x.ndim
    pad[ax] = (0, target - x.shape[ax])
    return jnp.pad(x, pad)


def crop_axis(x, ax: int, ln: int):
    """Slice ``ax`` down to ``ln`` (no-op when already there)."""
    if x.shape[ax] == ln:
        return x
    sl = [slice(None)] * x.ndim
    sl[ax] = slice(0, ln)
    return x[tuple(sl)]


def _a2a(x, axis_name, split_axis, concat_axis):
    return lax.all_to_all(x, axis_name, split_axis, concat_axis, tiled=True)


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

class CommStrategy:
    """One topology-switch execution policy.

    ``stage(x, axis_name, split_axis, concat_axis, post=...)`` performs the
    switch and then applies ``post`` -- the crop + next direction's 1-D
    transform continuation handed down by the solver.  Monolithic strategies
    run ``post`` on the whole switched block; ``overlap`` interleaves it
    chunk-wise with the collectives.  ``switch`` is the plain transpose
    (``post=None``), the API the MoE/attention layers use.

    ``chunk_axis`` (stage/switch keyword) is a PREFERRED chunk axis for the
    chunked strategies -- the batched multi-RHS solve passes its leading
    batch axis here, a free chunk dimension that is never split or gathered
    by any topology switch.  The preference is honored when ``n_chunks``
    divides the axis length exactly (such an axis never needs
    zero-padding); otherwise the usual uninvolved grid axis is used.

    ``valid_extent`` (stage/switch keyword) is the number of LIVE entries
    along ``split_axis`` -- the pruned/deferred-doubling execution model's
    contract: anything past it is padding the wire never needs to carry.
    The strategy crops the split axis down to it and re-pads to the
    equal-split multiple XLA's all-to-all requires (``axis_sizes``, the
    {mesh axis name: size} map handed to the constructor).  ``None`` ships
    the axis as-is (the dense path, and the historical call sites).

    ``permute`` (stage/switch keyword) is an axis permutation (jnp.transpose
    spec over the FULL array rank) applied to the block as part of the
    switch's PACK, before the crop/pad and the collective --
    ``split_axis``/``concat_axis``/``chunk_axis`` are therefore in the
    PERMUTED frame.  The layout-scheduled pipelines (DESIGN.md #9) fold the
    one relayout between consecutive directions in here, arranged so the
    collective always splits a contiguous major axis and gathers straight
    into the next transform's minor axis -- the solve then emits ZERO
    standalone transposes between stages.  ``None`` keeps the incoming
    axis order (the baseline / historical call sites).
    """

    name: str = "?"

    def __init__(self, n_chunks: int = 1, axis_sizes=None,
                 fold: str = "pack", abft=None):
        self.n_chunks = max(int(n_chunks), 1)
        self.axis_sizes = dict(axis_sizes or {})
        assert fold in FOLDS, fold
        self.fold = fold
        # checksum-carrying mode (DESIGN.md #13): ``(collector, tol)`` or
        # None.  When set, every collective this strategy issues ships a
        # length-P checksum row (one reduction per destination rank over
        # the prepared payload) through a sidecar all_to_all and verifies
        # it receive-side -- corruption on the wire is then attributed to
        # ``wire.<axis>`` instead of a compute stage.
        self.abft = abft

    def _collective(self, x, axis_name, split_axis, concat_axis):
        """One (possibly checksum-carrying) all-to-all.  The wire fault
        hook sits between the sender-side checksum and the exchange:
        exactly the window a real link flip occupies."""
        ab = self.abft
        p = self.axis_sizes.get(axis_name)
        if ab is not None and p and x.shape[split_axis % x.ndim] % p == 0:
            from repro.runtime import abft as _abft
            col, tol = ab
            cs = _abft.wire_checksums(x, split_axis, p)
            y = _a2a(_faults.taint(f"comm.wire.{self.name}", x),
                     axis_name, split_axis, concat_axis)
            cs_recv = lax.all_to_all(cs, axis_name, 0, 0, tiled=True)
            return _abft.wire_verify(y, cs_recv, concat_axis, p, col,
                                     f"wire.{axis_name}", tol)
        return _a2a(_faults.taint(f"comm.wire.{self.name}", x),
                    axis_name, split_axis, concat_axis)

    @staticmethod
    def _permute(x, permute):
        return x if permute is None else jnp.transpose(x, permute)

    def _pack(self, x, split_axis, concat_axis, chunk_axis, permute):
        """Resolve the relayout fold: returns ``(x, split, concat, chunk,
        unpack)`` where the coordinates address the frame the collective
        runs in and ``unpack`` is the permutation still owed AFTER it
        (None under fold="pack", which transposes up front).  Caller
        coordinates are always in the PERMUTED (post-relayout) frame."""
        if permute is None:
            return x, split_axis, concat_axis, chunk_axis, None
        if self.fold == "pack":
            return (self._permute(x, permute), split_axis, concat_axis,
                    chunk_axis, None)
        # fold="unpack": the collective runs in the incoming frame; map the
        # permuted-frame coordinates back through the permutation
        return (x, permute[split_axis], permute[concat_axis],
                None if chunk_axis is None else permute[chunk_axis],
                permute)

    def _prepare(self, x, axis_name, split_axis: int, valid_extent):
        """Crop ``split_axis`` to its valid extent, then zero-pad to the
        equal-split length of ``axis_name`` (no-ops when already there)."""
        if valid_extent is None:
            return x
        x = crop_axis(x, split_axis, valid_extent)
        p = self.axis_sizes.get(axis_name)
        if p:
            x = pad_axis(x, split_axis, -(-x.shape[split_axis] // p) * p)
        return x

    def _chunk_axis(self, x, split_axis: int, concat_axis: int,
                    chunk_axis) -> int:
        if (chunk_axis is not None
                and chunk_axis not in (split_axis, concat_axis)
                and x.shape[chunk_axis] % self.n_chunks == 0):
            return chunk_axis
        return _uninvolved_axis(x.ndim, split_axis, concat_axis)

    # -- to be overridden -------------------------------------------------
    def _switch(self, x, axis_name, split_axis, concat_axis,
                chunk_axis=None):
        raise NotImplementedError

    # -- shared surface ----------------------------------------------------
    def switch(self, x, axis_name, split_axis, concat_axis,
               chunk_axis=None, valid_extent=None, permute=None):
        return self.stage(x, axis_name, split_axis, concat_axis, post=None,
                          chunk_axis=chunk_axis, valid_extent=valid_extent,
                          permute=permute)

    def stage(self, x, axis_name, split_axis, concat_axis, post=None,
              chunk_axis=None, valid_extent=None, permute=None):
        # fault-injection hook: an armed spec for this strategy simulates
        # the collective dying at trace time (chaos suite; no-op otherwise)
        _faults.fail_point(f"comm.{self.name}")
        # the scheduled relayout rides the switch (pack or unpack side per
        # ``fold``): one transpose, adjacent to the collective either way
        x, split_axis, concat_axis, chunk_axis, unpack = self._pack(
            x, split_axis, concat_axis, chunk_axis, permute)
        x = self._prepare(x, axis_name, split_axis, valid_extent)
        y = self._switch(x, axis_name, split_axis, concat_axis,
                         chunk_axis=chunk_axis)
        y = self._permute(y, unpack)
        return post(y) if post is not None else y


@jax.custom_vjp
def _buffer_barrier(y):
    """``optimization_barrier`` as a differentiable identity: the barrier
    is a scheduling hint with no math, but it carries no differentiation
    rule, and the ABFT sandwich weight (``w = S^T r``, DESIGN.md #13) is
    built by one vjp through the whole distributed pipeline -- so the
    cotangent passes straight through."""
    return lax.optimization_barrier(y)


def _buffer_barrier_fwd(y):
    return _buffer_barrier(y), None


def _buffer_barrier_bwd(_, ct):
    return (ct,)


_buffer_barrier.defvjp(_buffer_barrier_fwd, _buffer_barrier_bwd)


class A2AStrategy(CommStrategy):
    name = "a2a"

    def _switch(self, x, axis_name, split_axis, concat_axis,
                chunk_axis=None):
        y = self._collective(x, axis_name, split_axis, concat_axis)
        # explicit pack/unpack materialization: force a contiguous copy so
        # the collective is surrounded by dedicated buffer ops (flups a2a)
        try:
            y = _buffer_barrier(y)
        except NotImplementedError:
            # older jax has no batching rule for optimization_barrier (hit
            # under the multi-pod vmap); the barrier is a scheduling hint
            # only, so dropping it preserves semantics
            pass
        return y


class FusedStrategy(CommStrategy):
    name = "fused"

    def _switch(self, x, axis_name, split_axis, concat_axis,
                chunk_axis=None):
        return self._collective(x, axis_name, split_axis, concat_axis)


class PipelinedStrategy(CommStrategy):
    """Chunked collectives only; neighboring transforms stay monolithic."""

    name = "pipelined"

    def _switch(self, x, axis_name, split_axis, concat_axis,
                chunk_axis=None):
        if self.n_chunks <= 1:
            return self._collective(x, axis_name, split_axis, concat_axis)
        ax = self._chunk_axis(x, split_axis, concat_axis, chunk_axis)
        chunks, ln = _split_chunks(x, ax, self.n_chunks)
        outs = [self._collective(c, axis_name, split_axis, concat_axis)
                for c in chunks]
        return crop_axis(jnp.concatenate(outs, axis=ax), ax, ln)


class OverlapStrategy(CommStrategy):
    """Software-pipelined switch: collective k+1 is issued before the
    post-stage (next direction's transform) of chunk k, so the transform of
    one chunk overlaps the wire time of the next."""

    name = "overlap"

    def _switch(self, x, axis_name, split_axis, concat_axis,
                chunk_axis=None):
        # plain transpose (no continuation): same wire pattern as pipelined
        return PipelinedStrategy(self.n_chunks, axis_sizes=self.axis_sizes,
                                 fold=self.fold, abft=self.abft)._switch(
            x, axis_name, split_axis, concat_axis, chunk_axis=chunk_axis)

    def stage(self, x, axis_name, split_axis, concat_axis, post=None,
              chunk_axis=None, valid_extent=None, permute=None):
        _faults.fail_point(f"comm.{self.name}")
        x, split_axis, concat_axis, chunk_axis, unpack = self._pack(
            x, split_axis, concat_axis, chunk_axis, permute)
        x = self._prepare(x, axis_name, split_axis, valid_extent)
        if post is None or self.n_chunks <= 1:
            y = self._switch(x, axis_name, split_axis, concat_axis,
                             chunk_axis=chunk_axis)
            y = self._permute(y, unpack)
            return post(y) if post is not None else y
        ax = self._chunk_axis(x, split_axis, concat_axis, chunk_axis)
        # under fold="unpack" each chunk is permuted as it lands (in the
        # gap its successor's collective is in flight) and the concat axis
        # rides the same permutation into the post frame
        ax_out = ax if unpack is None else unpack.index(ax)
        chunks, ln = _split_chunks(x, ax, self.n_chunks)
        outs = []
        inflight = self._collective(chunks[0], axis_name, split_axis,
                                    concat_axis)
        for k in range(1, self.n_chunks):
            nxt = self._collective(chunks[k], axis_name, split_axis,
                                   concat_axis)
            # overlaps chunk k's wire time
            outs.append(post(self._permute(inflight, unpack)))
            inflight = nxt
        outs.append(post(self._permute(inflight, unpack)))
        return crop_axis(jnp.concatenate(outs, axis=ax_out), ax_out, ln)


_STRATEGY_CLASSES = {
    cls.name: cls
    for cls in (A2AStrategy, PipelinedStrategy, FusedStrategy,
                OverlapStrategy)
}


def make_strategy(cfg: CommConfig, axis_sizes=None,
                  abft=None) -> CommStrategy:
    return _STRATEGY_CLASSES[cfg.strategy](cfg.n_chunks,
                                           axis_sizes=axis_sizes,
                                           fold=cfg.fold, abft=abft)


def topology_switch(x, axis_name, split_axis: int, concat_axis: int,
                    cfg: CommConfig, chunk_axis=None, valid_extent=None,
                    axis_sizes=None, permute=None):
    """Distributed transpose: split ``split_axis`` over ``axis_name`` ranks,
    gather ``concat_axis``.  Must run inside shard_map.  ``valid_extent``
    (with ``axis_sizes``) crops the split axis to its live entries before
    the exchange; ``permute`` folds a relayout into the unpack -- see
    ``CommStrategy``."""
    return make_strategy(cfg, axis_sizes=axis_sizes).switch(
        x, axis_name, split_axis, concat_axis, chunk_axis=chunk_axis,
        valid_extent=valid_extent, permute=permute)


# ---------------------------------------------------------------------------
# plan-time autotuner (flups switchsort analogue)
# ---------------------------------------------------------------------------

_AUTOTUNE_CACHE: dict = {}
_AUTOTUNE_LOCK = threading.Lock()


def autotune_candidates(max_chunks: int = 4, folds=("pack",)):
    """Default (strategy, n_chunks) sweep: monolithic strategies once,
    chunked strategies at 2, 4, ... up to ``max_chunks``.  ``folds`` widens
    the grid across relayout fold sides (layout-scheduled solvers sweep
    ``("pack", "unpack")`` -- which side of the collective the fused
    transpose is cheaper on is shape- and backend-dependent)."""
    cands = []
    for fold in folds:
        cands += [CommConfig("a2a", 1, fold), CommConfig("fused", 1, fold)]
        nc = 2
        while nc <= max_chunks:
            cands.append(CommConfig("pipelined", nc, fold))
            cands.append(CommConfig("overlap", nc, fold))
            nc *= 2
    return tuple(cands)


def clear_autotune_cache():
    with _AUTOTUNE_LOCK:
        _AUTOTUNE_CACHE.clear()


# on-disk JSON layout: {"schema": CACHE_SCHEMA, "entries": {key: entry}}.
# Schema 1 (the seed through PR 7) was the flat {key: entry} dict with no
# version field and no ``fold`` in early entries; it is migrated in memory
# on load (warned ONCE per file, counted in ``census["migrated"]``) and
# rewritten as the current schema on the next store.
CACHE_SCHEMA = 2


def _cache_file_load(path: str) -> dict:
    try:
        with open(path) as fh:
            data = json.load(fh)
    except OSError:                 # absent cache: normal first-run state
        return {}
    except ValueError:
        # torn/corrupt JSON (e.g. a write interrupted before the atomic
        # store below landed, or on-disk rot): warn once and fall through
        # to a live sweep instead of raising at startup
        _warn_once(f"comm: autotune cache {path} is corrupt/truncated; "
                   "ignoring it (a live sweep will rewrite it)")
        return {}
    if not isinstance(data, dict):
        _warn_once(f"comm: autotune cache {path} holds non-dict JSON; "
                   "ignoring it (a live sweep will rewrite it)")
        return {}
    # chaos hook: an armed ``corrupt_cache`` spec rots the loaded entries
    # in place; the consumer must treat them as malformed and re-sweep
    return _faults.mangle_cache_entry(data)


def cache_load_entries(path: str, census=None) -> dict:
    """Load the cache file and return its ENTRIES dict, migrating legacy
    (schema-1, flat) files in memory.  ``census["migrated"]`` counts the
    entries carried across a migration (0 on a current-schema file)."""
    data = _cache_file_load(path)
    if census is not None:
        census.setdefault("migrated", 0)
    if not data:
        return {}
    if "schema" in data or "entries" in data:
        entries = data.get("entries")
        if data.get("schema") == CACHE_SCHEMA and isinstance(entries, dict):
            return entries
        _warn_once(f"comm: autotune cache {path} has unsupported schema "
                   f"{data.get('schema')!r}; ignoring it (a live sweep "
                   "will rewrite it)")
        return {}
    # legacy schema-1 flat file: every value that looks like an entry is
    # carried over; pre-fold entries pick up the historical default
    entries = {}
    for k, v in data.items():
        if isinstance(v, dict):
            e = dict(v)
            if "strategy" in e:
                e.setdefault("fold", "pack")
            entries[k] = e
    if entries:
        _warn_once(f"comm: autotune cache {path} uses the legacy flat "
                   f"schema; migrated {len(entries)} entries in memory "
                   f"(rewritten as schema {CACHE_SCHEMA} on the next "
                   "store)")
    if census is not None:
        census["migrated"] += len(entries)
    return entries


_CACHE_FILE_LOCK = threading.Lock()


def cache_store_entry(path: str, key: str, entry: dict):
    """Read-merge-write one entry into the schema-versioned JSON cache,
    atomically.

    Concurrent server workers (threads in this process via the lock,
    sibling processes via tmp+``os.replace``) never interleave partial
    writes: a reader sees either the old file or the new one, complete --
    a crash mid-store leaves at worst a stray ``*.tmp.<pid>`` file, never
    a truncated cache that breaks the next startup's ``json.load``.
    Storing into a legacy flat file migrates it to the current schema."""
    with _CACHE_FILE_LOCK:
        entries = cache_load_entries(path)
        entries[key] = entry
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            with open(tmp, "w") as fh:
                json.dump({"schema": CACHE_SCHEMA, "entries": entries},
                          fh, indent=1, sort_keys=True)
            os.replace(tmp, path)   # atomic commit (same filesystem)
        except OSError as e:        # cache is best-effort, never fatal
            _warn_once(f"comm: cannot persist autotune cache to {path}: {e}")
            try:
                os.unlink(tmp)
            except OSError:
                pass


def _cache_file_store(path: str, key: str, cfg: CommConfig, timings: dict,
                      skipped=()):
    entry = {"strategy": cfg.strategy, "n_chunks": cfg.n_chunks,
             "fold": cfg.fold,
             "timings_us": {k: round(v * 1e6, 1)
                            for k, v in timings.items()}}
    if cfg.chunk_axis != "auto":
        entry["chunk_axis"] = cfg.chunk_axis
    if skipped:                     # budget-abandoned candidates, on record
        entry["skipped_budget"] = list(skipped)
    cache_store_entry(path, key, entry)


def _timed_call(fn, arg, budget_s):
    """Run ``fn(arg)`` with a wall-clock budget.  Returns (value, None) or
    (None, "timeout").  The call runs in a worker thread so a pathological
    candidate (hung collective, runaway compile) cannot stall plan
    construction -- on timeout the sweep moves on and the stray thread is
    abandoned (it holds no locks the sweep needs)."""
    if not budget_s or budget_s <= 0:
        return fn(arg), None
    import concurrent.futures as cf
    ex = cf.ThreadPoolExecutor(max_workers=1)
    fut = ex.submit(fn, arg)
    try:
        return fut.result(timeout=budget_s), None
    except cf.TimeoutError:
        fut.cancel()
        return None, "timeout"
    finally:
        ex.shutdown(wait=False)


def autotune_comm(key, time_fn, candidates=None, cache_path=None,
                  results=None, budget_s=None, census=None) -> CommConfig:
    """Pick the fastest (strategy, n_chunks) pair for one plan/mesh key.

    ``time_fn(cfg) -> seconds`` lowers+times one solve under ``cfg`` (the
    solver provides it); the winner is cached in-memory per ``key`` and,
    when ``cache_path`` (default $REPRO_COMM_CACHE) is set, persisted as
    JSON so later processes skip the sweep.  ``results``, when a dict, is
    filled with the per-candidate timings of a live sweep (empty on a cache
    hit).  A candidate that raises is skipped; if every candidate fails the
    default ``a2a`` is returned.

    ``budget_s`` (default $REPRO_COMM_BUDGET, unset = unlimited) is the
    per-candidate wall-clock budget: a candidate that does not produce a
    timing within it is skipped (warned once) so ONE pathological
    (strategy, n_chunks, fold) pair cannot stall plan construction.
    ``census``, when a dict, records the sweep's full account:
    ``timed`` (label -> seconds), ``failed`` (label -> error),
    ``skipped_budget`` (labels abandoned on budget) and ``migrated``
    (entries carried across a legacy cache-schema migration).
    """
    if candidates is None:
        candidates = autotune_candidates()
    if budget_s is None:
        try:
            budget_s = float(os.environ.get("REPRO_COMM_BUDGET", "") or 0)
        except ValueError:
            budget_s = 0
    # the candidate grid is part of the identity: widening the sweep (e.g.
    # raising comm_autotune_max_chunks, adding fold sides, or a guided
    # search shortlisting a different frontier) must invalidate the cached
    # winner
    labels = tuple(cfg_label(c) for c in candidates)
    key = repr((key, labels))
    if cache_path is None:
        cache_path = os.environ.get("REPRO_COMM_CACHE") or None
    with _AUTOTUNE_LOCK:
        hit = _AUTOTUNE_CACHE.get(key)
    if hit is not None:
        return hit
    if cache_path:
        entry = cache_load_entries(cache_path, census=census).get(key)
        if entry is not None:
            try:
                cfg = CommConfig(entry["strategy"], int(entry["n_chunks"]),
                                 str(entry.get("fold", "pack")),
                                 str(entry.get("chunk_axis", "auto")))
            except (KeyError, TypeError, ValueError, AssertionError):
                # malformed / older-schema entry: fall through to a live
                # sweep (the cache is best-effort, never fatal)
                cfg = None
            if cfg is not None:
                with _AUTOTUNE_LOCK:
                    _AUTOTUNE_CACHE[key] = cfg
                return cfg

    timings: dict = {}
    skipped, failed = [], {}
    for cfg, label in zip(candidates, labels):
        try:
            t, why = _timed_call(time_fn, cfg, budget_s)
        except Exception as e:      # noqa: BLE001 -- candidate may not lower
            failed[label] = f"{type(e).__name__}: {e}"[:200]
            _warn_once(f"comm: autotune candidate {label} failed: {e}")
            continue
        if why == "timeout":
            skipped.append(label)
            _warn_once(f"comm: autotune candidate {label} exceeded the "
                       f"{budget_s:g}s budget; skipped")
            continue
        timings[label] = float(t)
    if results is not None:
        results.update(timings)
    if census is not None:
        census.update(timed=dict(timings), failed=failed,
                      skipped_budget=list(skipped))
    if not timings:
        return CommConfig()
    best_label = min(timings, key=timings.get)
    best = label_to_cfg(best_label)
    with _AUTOTUNE_LOCK:
        _AUTOTUNE_CACHE[key] = best
    if cache_path:
        _cache_file_store(cache_path, key, best, timings, skipped)
    return best


def all_reduce_mean(x, axis_name):
    return lax.pmean(x, axis_name)
