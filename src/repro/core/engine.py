"""Pluggable transform engine: the single hot path of both solvers.

The paper's pipeline is (per direction) 1-D transform -> pointwise Green
multiply -> inverse transforms; this module decides HOW each stage executes:

  engine="xla"     pure jnp/XLA ops (rfft/irfft half-spectrum transforms,
                   fused elementwise) -- the default everywhere.
  engine="pallas"  the hand-written TPU kernels take over the hot loops:
                   ``twiddle_pack`` for the r2r post-twiddle,
                   ``fft_stockham`` for power-of-two (r)FFT backends, and
                   ``spectral_scale``/``green_multiply`` for the fused
                   Green multiply.  Non-power-of-two FFT lengths fall back
                   to jnp transparently, so any plan works on any engine.

A plan is compiled once into a ``TransformSchedule``: per-direction twiddle
tables (plan-time numpy constants handed to the kernels) plus the combined
normalization of every backward r2r transform.  That normalization is folded
into the Green's function by ``build_green`` (one multiply for the whole
solve), so the backward pass emits ZERO standalone normalization multiplies
-- see tests/test_engine.py which counts them in the jaxpr.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

__all__ = ["TransformEngine", "TransformSchedule", "as_engine",
           "build_schedule", "folded_normfact", "ENGINES"]

ENGINES = ("xla", "pallas")


@dataclass(frozen=True)
class TransformEngine:
    """Execution backend selection for the transform + pointwise stages.

    ``interpret``: run Pallas kernels in interpret mode (CPU validation);
    on a real TPU runtime pass ``interpret=False`` to lower to Mosaic.
    """

    name: str = "xla"
    interpret: bool = True

    def __post_init__(self):
        if self.name not in ENGINES:
            raise ValueError(
                f"unknown engine {self.name!r}; expected one of {ENGINES}")

    @property
    def use_pallas(self) -> bool:
        return self.name == "pallas"


def as_engine(engine) -> TransformEngine:
    """Accept ``"xla"`` / ``"pallas"`` / TransformEngine / None."""
    if engine is None:
        return TransformEngine()
    if isinstance(engine, TransformEngine):
        return engine
    return TransformEngine(str(engine))


@dataclass(frozen=True)
class TransformSchedule:
    """Plan-time constants for one solve: per-direction twiddle tables and
    the folded normalization (quadrature h weights stay in build_green)."""

    engine: TransformEngine
    fwd_tables: tuple    # per logical dim: twiddle dict for the forward kind
    bwd_tables: tuple    # per logical dim: twiddle dict for the inverse kind
    norm: float          # prod of r2r normfacts, folded into the Green

    def green_multiply(self, yhat, green):
        """The fused pointwise pass (Green x normalization in one multiply)."""
        if self.engine.use_pallas:
            from repro.kernels import ops
            return ops.green_multiply(yhat, green,
                                      interpret=self.engine.interpret)
        if jnp.iscomplexobj(yhat):
            return yhat * green
        return yhat * green.astype(yhat.dtype)


def folded_normfact(plan) -> float:
    """The combined backward normalization of a plan -- the single factor
    ``build_green`` folds into the Green's function (every direction, DFT
    included; their normfact is 1.0)."""
    norm = 1.0
    for p in plan.dirs:
        norm *= p.normfact
    return norm


def build_schedule(plan, engine=None) -> TransformSchedule:
    """Compile a ``PoissonPlan`` into its per-direction transform schedule."""
    from . import transforms as tr
    from .bc import INVERSE_KIND

    engine = as_engine(engine)
    fwd, bwd = [], []
    for p in plan.dirs:
        if p.kind is None:       # DFT direction: no r2r twiddles
            fwd.append(None)
            bwd.append(None)
        else:
            fwd.append(tr.twiddle_tables(p.kind, p.n_fft))
            bwd.append(tr.twiddle_tables(INVERSE_KIND[p.kind], p.n_fft))
    return TransformSchedule(engine, tuple(fwd), tuple(bwd),
                             folded_normfact(plan))
