"""Pluggable transform engine: the single hot path of both solvers.

The paper's pipeline is (per direction) 1-D transform -> pointwise Green
multiply -> inverse transforms; this module decides HOW each stage executes:

  engine="xla"     pure jnp/XLA ops (rfft/irfft half-spectrum transforms,
                   fused elementwise) -- the default everywhere.
  engine="pallas"  the hand-written TPU kernels take over the hot loops:
                   ``twiddle_pack`` for the r2r post-twiddle,
                   ``fft_stockham`` for power-of-two (r)FFT backends, and
                   ``spectral_scale``/``green_multiply`` for the fused
                   Green multiply.  Non-power-of-two FFT lengths fall back
                   to jnp transparently, so any plan works on any engine.

A plan is compiled once into a ``TransformSchedule``: per-direction twiddle
tables (plan-time numpy constants handed to the kernels) plus the combined
normalization of every backward r2r transform.  That normalization is folded
into the Green's function by ``build_green`` (one multiply for the whole
solve), so the backward pass emits ZERO standalone normalization multiplies
-- see tests/test_engine.py which counts them in the jaxpr.

The schedule is also the distributed solver's STAGE API: ``fwd_chunk`` /
``bwd_chunk`` apply one direction's 1-D transform to the full local block or
to any chunk of it cut along an uninvolved axis -- the unit the ``overlap``
comm strategy interleaves with the per-chunk collectives of a topology
switch (see ``repro.core.comm``).

Layout scheduling (DESIGN.md #9): data layout is a PLAN-TIME quantity.  A
``LayoutSchedule`` assigns every stage the axis permutation it runs in
(active dim minor-most); the scheduled pipelines call the ``fwd_last`` /
``bwd_last`` stage API (no per-direction moveaxis round trips) and fold
the one relayout per direction change into the topology switch
(``CommStrategy.stage(permute=...)``) -- or, single-process, into one
composed transpose.  ``fwd_last_green`` additionally fuses the Green
multiply into the last forward direction's Pallas FFT as an in-register
epilogue.  The ``fwd_1d``/``bwd_1d`` moveaxis adapters remain the
natural-layout API (baseline pipelines, spectral differentiation,
standalone callers).

Batched multi-RHS execution: every op here is rank-polymorphic.  A plan
describes ``len(plan.dirs)`` grid dimensions; any leading axes of the array
are batch axes (``B`` independent right-hand sides sharing one plan), and a
direction's array axis is ``batch_ndim + p.dim``.  The 1-D transforms are
last-axis ops over flattened rows, so a batched solve runs the SAME number
of (bigger) FFT calls as a single solve -- the multi-RHS amortization of
the original FLUPS / P3DFFT batched transform APIs.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.runtime import faults as _faults

__all__ = ["TransformEngine", "TransformSchedule", "LayoutSchedule",
           "as_engine", "build_schedule", "schedule_layouts", "relayout",
           "on_last_axis", "folded_normfact", "fwd_1d", "bwd_1d",
           "materialize_doubling", "crop_doubling", "ENGINES",
           "RELAYOUT_MODES"]

RELAYOUT_MODES = ("scheduled", "baseline")

ENGINES = ("xla", "pallas")


@dataclass(frozen=True)
class TransformEngine:
    """Execution backend selection for the transform + pointwise stages.

    ``interpret``: run Pallas kernels in interpret mode (CPU validation);
    on a real TPU runtime pass ``interpret=False`` to lower to Mosaic.
    ``max_radix``: Stockham FFT radix cap (4 = mixed radix-4/2, the
    default; 2 = pure radix-2, twice the stages at half the per-stage
    arithmetic) -- a plan-space search dimension (DESIGN.md #12); only
    the Pallas kernels consume it, the XLA engine ignores it.
    """

    name: str = "xla"
    interpret: bool = True
    max_radix: int = 4

    def __post_init__(self):
        if self.name not in ENGINES:
            raise ValueError(
                f"unknown engine {self.name!r}; expected one of {ENGINES}")
        if self.max_radix not in (2, 4):
            raise ValueError(f"max_radix must be 2 or 4, "
                             f"got {self.max_radix!r}")

    @property
    def use_pallas(self) -> bool:
        return self.name == "pallas"


def as_engine(engine) -> TransformEngine:
    """Accept ``"xla"`` / ``"pallas"`` / TransformEngine / None."""
    if engine is None:
        return TransformEngine()
    if isinstance(engine, TransformEngine):
        return engine
    return TransformEngine(str(engine))


# ---------------------------------------------------------------------------
# per-direction 1-D ops (jnp, last axis; natural-layout callers go through
# the ``on_last_axis`` moveaxis adapter)
# ---------------------------------------------------------------------------

def _batch_ndim(x, sched) -> int:
    """Leading batch axes of ``x`` relative to the schedule's grid rank."""
    if sched is None or not sched.dirs:
        return 0
    bnd = x.ndim - len(sched.dirs)
    assert 0 <= bnd, (x.shape, len(sched.dirs))
    return bnd


def on_last_axis(x, axis, fn):
    """Run ``fn`` on ``x`` with ``axis`` shuffled minor-most, restoring the
    axis afterwards -- the mirrored moveaxis plumbing shared by ``fwd_1d``/
    ``bwd_1d`` here and ``spectral.apply_derivative``.

    Measured (EXPERIMENTS.md section Perf, flups cell): transforming along
    the native axis (jnp.fft axis=d) REGRESSES bytes by 11% -- XLA
    transposes internally for non-minor FFT axes and loses the fusion of
    the explicit moveaxis (a no-op when ``axis`` is already last).  The
    layout-SCHEDULED pipelines (DESIGN.md #9) avoid this adapter entirely:
    they keep the active axis minor-most and fold the one real relayout
    into the topology switch's unpack.
    """
    y = fn(jnp.moveaxis(x, axis, -1))
    return jnp.moveaxis(y, -1, axis)


def _fwd_last(x, p, sched=None):
    """Forward 1-D transform of direction ``p`` applied to the LAST axis
    of ``x`` (the layout-scheduled hot path: the caller guarantees the
    active axis is minor-most).

    Valid-extent contract: the incoming axis carries ``p.valid_in`` live
    points (``n_pts`` deferred, ``n_fft`` when the plan pre-padded the
    Hockney doubling up front) and the outgoing axis carries ``p.n_out``.
    """
    from . import transforms as tr
    engine = sched.engine if sched is not None else None
    x = _faults.taint(f"fwd.{p.dim}", x)
    if engine is not None and engine.use_pallas:
        _faults.fail_point(f"pallas.fwd.{p.dim}")
    if p.pre_padded:
        # dense up-front doubling: the zero extension is already in the
        # array, the transform is a plain full-length one
        if p.category in ("sym", "semi"):
            raise AssertionError("pre_padded is a DFT-direction mode")
        return tr._rfft(x, engine) if p.dft == "r2c" else tr._cfft(x, engine)
    if p.flip:
        x = x[..., ::-1]
    x = x[..., p.in_start:p.in_start + p.n_in]
    if p.category in ("sym", "semi"):
        if p.n_fft > p.n_in:
            pad = [(0, 0)] * (x.ndim - 1) + [(0, p.n_fft - p.n_in)]
            x = jnp.pad(x, pad)
        tables = sched.fwd_tables[p.dim] if sched is not None else None
        return tr.r2r_forward(x, p.kind, engine=engine, tables=tables)
    if p.dft == "r2c":
        # pruned forward: the length-n_fft spectrum from the n_in nonzero
        # inputs (Pallas skips the zero tail; XLA pads -- bit-identical)
        return tr._rfft_padded(x, p.n_fft, engine)
    return tr._cfft_padded(x, p.n_fft, engine)


def _bwd_last(y, p, sched=None):
    """Inverse 1-D transform of direction ``p`` on the LAST axis; emits
    ``p.valid_in`` points (the ``n_pts`` user axis under deferred doubling,
    the full ``n_fft`` reconstruction when the plan padded up front)."""
    # NOTE: no normalization multiply here -- every direction's normfact is
    # folded into the Green's function at plan time (build_green).
    from . import transforms as tr
    engine = sched.engine if sched is not None else None
    y = _faults.taint(f"bwd.{p.dim}", y)
    if engine is not None and engine.use_pallas:
        _faults.fail_point(f"pallas.bwd.{p.dim}")
    if p.category in ("sym", "semi"):
        tables = sched.bwd_tables[p.dim] if sched is not None else None
        x = tr.r2r_backward(y, p.kind, engine=engine, tables=tables)
        x = x[..., :p.n_in]
    elif p.pre_padded:
        # dense mode keeps the doubled extent; cropped once at solve end
        return (tr._irfft(y, p.n_fft, engine) if p.dft == "r2c"
                else tr._cfft(y, engine, inverse=True))
    elif p.dft == "r2c":
        # pruned backward: reconstruct only the n_in retained samples
        x = tr._irfft_crop(y, p.n_fft, p.n_in, engine)
    else:
        x = tr._icfft_crop(y, p.n_in, engine)
    # place into the user-sized axis
    left = p.in_start
    right = p.n_pts - p.in_start - p.n_in - (1 if p.per_dup else 0)
    if left or right:
        pad = [(0, 0)] * (x.ndim - 1) + [(left, right)]
        x = jnp.pad(x, pad)
    if p.per_dup:  # node-periodic: duplicate the first point at the end
        x = jnp.concatenate([x, x[..., :1]], axis=-1)
    if p.flip:
        x = x[..., ::-1]
    return x


def fwd_1d(x, p, sched=None):
    """Forward 1-D transform of direction ``p`` (a ``Plan1D``), applied to
    the whole block or to any chunk cut along an axis other than ``p.dim``,
    in NATURAL layout (the axis is shuffled minor-most and back).  Leading
    batch axes (multi-RHS) pass through untouched -- the schedule is what
    knows the grid rank, so batched arrays REQUIRE ``sched``; with
    ``sched=None`` the array rank must equal the plan's.
    """
    return on_last_axis(x, _batch_ndim(x, sched) + p.dim,
                        lambda v: _fwd_last(v, p, sched))


def bwd_1d(y, p, sched=None):
    """Inverse 1-D transform of direction ``p`` in natural layout;
    chunk-safe like ``fwd_1d`` (and like it, batched arrays require
    ``sched``)."""
    return on_last_axis(y, _batch_ndim(y, sched) + p.dim,
                        lambda v: _bwd_last(v, p, sched))


# ---------------------------------------------------------------------------
# layout scheduling (DESIGN.md #9): data layout as a plan-time quantity
# ---------------------------------------------------------------------------

def to_last(perm, d):
    """The permutation ``perm`` with logical dim ``d`` shuffled minor-most
    and every other dim left in place (one transpose away from ``perm``)."""
    return tuple(x for x in perm if x != d) + (d,)


def switch_layout(perm, a, b):
    """Layout after the topology switch retiring active dim ``a`` for
    ``b``: ``a`` goes MAJOR-most (the axis the switch splits, so every
    rank's share is one contiguous slab) and ``b`` MINOR-most (the
    gathered axis, exactly where the next 1-D transform consumes it).
    One transpose away from any ``(.., .., a)`` stage layout."""
    rest = [d for d in perm if d not in (a, b)]
    return (a, *rest, b)


@dataclass(frozen=True)
class LayoutSchedule:
    """Plan-time axis-permutation schedule of one solve.

    ``fwd[i]`` / ``bwd[i]`` is the grid-axis permutation the block is in
    DURING forward/backward stage ``i`` (executed in pipeline order):
    ``perm[a]`` is the logical dim stored at array axis ``a`` (batch axes
    lead and are never permuted).  Every stage keeps its active dim
    minor-most, so the 1-D transforms never move data; every switch
    target is a ``switch_layout`` (outgoing dim major, incoming dim
    minor), so the one relayout between consecutive stages is a single
    composed transpose folded into the switch's PACK -- after it, the
    collective splits a contiguous major axis and gathers straight into
    the next transform's minor axis, and the pipeline emits zero
    standalone transposes between stages (``hlo_stats.transpose_stats``).
    ``bwd[0] == spectral``: the first backward stage reuses the spectral
    layout, so the Green multiply and both last-direction transforms
    share it.
    """

    fwd: tuple
    bwd: tuple

    @property
    def spectral(self):
        """Layout of the pointwise Green multiply (== ``fwd[-1]``)."""
        return self.fwd[-1]


def schedule_layouts(order, ndim: int = 3) -> LayoutSchedule:
    """The minimal-relayout schedule: stage 0 moves only the first active
    dim minor-most; every later stage is the ``switch_layout`` of the
    direction pair it sits between (one fused transpose per switch)."""
    perm = to_last(tuple(range(ndim)), order[0])
    fwd = [perm]
    for a, b in zip(order, order[1:]):
        perm = switch_layout(perm, a, b)
        fwd.append(perm)
    bwd = [perm]                      # spectral layout reused by bwd[0]
    rev = tuple(reversed(order))
    for a, b in zip(rev, rev[1:]):
        perm = switch_layout(perm, a, b)
        bwd.append(perm)
    return LayoutSchedule(tuple(fwd), tuple(bwd))


def relayout(x, src, dst):
    """One composed transpose taking the grid layout ``src`` to ``dst``
    (identity-free: returns ``x`` unchanged when the layouts agree).
    Leading batch axes pass through untouched."""
    src, dst = tuple(src), tuple(dst)
    if src == dst:
        return x
    off = x.ndim - len(src)
    axes = tuple(range(off)) + tuple(off + src.index(d) for d in dst)
    return jnp.transpose(x, axes)


def materialize_doubling(x, dirs):
    """Zero-pad every ``pre_padded`` direction of a user-shaped array from
    ``n_pts`` to ``n_fft`` (the dense up-front Hockney doubling; a no-op on
    deferred plans).  Leading batch axes pass through."""
    off = x.ndim - len(dirs)
    for d, p in enumerate(dirs):
        if p.pre_padded and x.shape[off + d] < p.n_fft:
            pad = [(0, 0)] * x.ndim
            pad[off + d] = (0, p.n_fft - x.shape[off + d])
            x = jnp.pad(x, pad)
    return x


def crop_doubling(x, dirs):
    """Crop every ``pre_padded`` direction back to its user extent (the
    final slice of a dense solve; a no-op on deferred plans)."""
    off = x.ndim - len(dirs)
    for d, p in enumerate(dirs):
        if p.pre_padded and x.shape[off + d] > p.n_pts:
            sl = [slice(None)] * x.ndim
            sl[off + d] = slice(0, p.n_pts)
            x = x[tuple(sl)]
    return x


@dataclass(frozen=True)
class TransformSchedule:
    """Plan-time constants for one solve: per-direction twiddle tables, the
    folded normalization (quadrature h weights stay in build_green) and the
    layout schedule of the scheduled pipelines."""

    engine: TransformEngine
    fwd_tables: tuple    # per logical dim: twiddle dict for the forward kind
    bwd_tables: tuple    # per logical dim: twiddle dict for the inverse kind
    norm: float          # prod of r2r normfacts, folded into the Green
    dirs: tuple = ()     # per logical dim: the plan's Plan1D
    order: tuple = ()    # the plan's forward execution order
    layouts: LayoutSchedule = None   # per-stage axis permutations

    # -- fused transform+switch stage API (chunk-safe by construction) -----
    #
    # Every stage takes an optional ABFT collector (DESIGN.md #13): with
    # ``col=None`` (the default everywhere) the plain stage is traced --
    # not one checksum op is emitted, so the verify-off pipelines stay
    # bit-exact.  With a collector the stage runs under its linearity /
    # Parseval sandwich with inline selective recompute.

    def fwd_chunk(self, x, d: int, col=None, tol=None):
        """Forward 1-D transform of logical direction ``d`` on a full block
        or an uninvolved-axis chunk (the overlap strategy's stage unit), in
        NATURAL layout (moveaxis round trip -- the baseline pipelines)."""
        if col is not None:
            from repro.runtime import abft
            return abft.checked_fwd_chunk(x, d, self, col, tol)
        return fwd_1d(x, self.dirs[d], self)

    def bwd_chunk(self, x, d: int, col=None, tol=None):
        """Inverse 1-D transform of logical direction ``d``; chunk-safe."""
        if col is not None:
            from repro.runtime import abft
            return abft.checked_bwd_chunk(x, d, self, col, tol)
        return bwd_1d(x, self.dirs[d], self)

    def fwd_last(self, x, d: int, col=None, tol=None):
        """Forward 1-D transform of direction ``d`` on the LAST axis (the
        layout-scheduled stage unit: the pipeline guarantees the active
        axis is already minor-most, so no data moves here)."""
        if col is not None:
            from repro.runtime import abft
            return abft.checked_fwd_last(x, d, self, col, tol)
        return _fwd_last(x, self.dirs[d], self)

    def bwd_last(self, x, d: int, col=None, tol=None):
        """Inverse 1-D transform of direction ``d`` on the LAST axis."""
        if col is not None:
            from repro.runtime import abft
            return abft.checked_bwd_last(x, d, self, col, tol)
        return _bwd_last(x, self.dirs[d], self)

    # live-extent bookkeeping lives on the plan: ``self.dirs[d].valid_in``
    # is the physical extent a topology switch ships for dim ``d`` (see
    # Plan1D; spectral extents are the plain ``n_out`` field)

    def green_multiply(self, yhat, green, col=None, tol=None):
        """The fused pointwise pass (Green x normalization in one multiply)."""
        if col is not None:
            from repro.runtime import abft
            return abft.checked_green(yhat, green, self, col, tol)
        yhat = _faults.taint("green", yhat)
        if self.engine.use_pallas:
            _faults.fail_point("pallas.green")
            from repro.kernels import ops
            return ops.green_multiply(yhat, green,
                                      interpret=self.engine.interpret)
        if jnp.iscomplexobj(yhat):
            return yhat * green
        return yhat * green.astype(yhat.dtype)

    def can_fuse_green(self, d: int) -> bool:
        """True when the forward transform of ``d`` can run the Green
        multiply as a Pallas FFT epilogue: a power-of-two DFT direction
        whose live extent is either the full FFT length or its pruned half
        (the Hockney zero-tail first stage composes with the epilogue)."""
        p = self.dirs[d]
        n = p.n_fft
        return (self.engine.use_pallas
                and p.category in ("per", "unb")
                and n >= 2 and (n & (n - 1)) == 0
                and not p.flip and p.in_start == 0
                and (p.n_in == n or n == 2 * p.n_in))

    def fwd_last_green(self, x, d: int, green, col=None, tol=None):
        """Forward transform of the LAST forward direction fused with the
        Green multiply: on the Pallas engine the ``spectral_scale`` pass
        runs in the FFT's final-stage registers (one HBM round trip for
        transform + pointwise); anywhere else it is the plain transform
        followed by ``green_multiply``.  ``green`` must be in the same
        layout as ``x`` with the spectral ``d`` axis minor-most."""
        if col is not None:
            # the checksum sandwich needs the spectral field BEFORE the
            # Green multiply, so checking bypasses the fused epilogue
            return self.green_multiply(self.fwd_last(x, d, col, tol), green,
                                       col, tol)
        p = self.dirs[d]
        want_cplx = p.dft == "c2c"
        if (not self.can_fuse_green(d)
                or bool(jnp.iscomplexobj(x)) != want_cplx):
            return self.green_multiply(self.fwd_last(x, d), green)
        x = _faults.taint(f"fwd.{p.dim}", x)
        x = _faults.taint("green", x)
        _faults.fail_point(f"pallas.fwd.{p.dim}")
        _faults.fail_point("pallas.green")
        from repro.kernels import ops
        n_live = p.n_fft if p.pre_padded else p.n_in
        x = x[..., :n_live]
        pad_to = None if n_live == p.n_fft else p.n_fft
        assert green.shape[-1] == p.n_out, (green.shape, p.n_out)
        if p.dft == "r2c":
            return ops.rfft_green(x, green, interpret=self.engine.interpret,
                                  pad_to=pad_to,
                                  max_radix=self.engine.max_radix)
        return ops.fft1d_green(x, green, interpret=self.engine.interpret,
                               pad_to=pad_to,
                               max_radix=self.engine.max_radix)


def folded_normfact(plan) -> float:
    """The combined backward normalization of a plan -- the single factor
    ``build_green`` folds into the Green's function (every direction, DFT
    included; their normfact is 1.0)."""
    norm = 1.0
    for p in plan.dirs:
        norm *= p.normfact
    return norm


def build_schedule(plan, engine=None) -> TransformSchedule:
    """Compile a ``PoissonPlan`` into its per-direction transform schedule."""
    from . import transforms as tr
    from .bc import INVERSE_KIND

    engine = as_engine(engine)
    fwd, bwd = [], []
    for p in plan.dirs:
        if p.kind is None:       # DFT direction: no r2r twiddles
            fwd.append(None)
            bwd.append(None)
        else:
            fwd.append(tr.twiddle_tables(p.kind, p.n_fft))
            bwd.append(tr.twiddle_tables(INVERSE_KIND[p.kind], p.n_fft))
    return TransformSchedule(engine, tuple(fwd), tuple(bwd),
                             folded_normfact(plan), plan.dirs, plan.order,
                             schedule_layouts(plan.order, len(plan.dirs)))
