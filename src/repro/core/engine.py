"""Pluggable transform engine: the single hot path of both solvers.

The paper's pipeline is (per direction) 1-D transform -> pointwise Green
multiply -> inverse transforms; this module decides HOW each stage executes:

  engine="xla"     pure jnp/XLA ops (rfft/irfft half-spectrum transforms,
                   fused elementwise) -- the default everywhere.
  engine="pallas"  the hand-written TPU kernels take over the hot loops:
                   ``twiddle_pack`` for the r2r post-twiddle,
                   ``fft_stockham`` for power-of-two (r)FFT backends, and
                   ``spectral_scale``/``green_multiply`` for the fused
                   Green multiply.  Non-power-of-two FFT lengths fall back
                   to jnp transparently, so any plan works on any engine.

A plan is compiled once into a ``TransformSchedule``: per-direction twiddle
tables (plan-time numpy constants handed to the kernels) plus the combined
normalization of every backward r2r transform.  That normalization is folded
into the Green's function by ``build_green`` (one multiply for the whole
solve), so the backward pass emits ZERO standalone normalization multiplies
-- see tests/test_engine.py which counts them in the jaxpr.

The schedule is also the distributed solver's STAGE API: ``fwd_chunk`` /
``bwd_chunk`` apply one direction's 1-D transform to the full local block or
to any chunk of it cut along an uninvolved axis -- the unit the ``overlap``
comm strategy interleaves with the per-chunk collectives of a topology
switch (see ``repro.core.comm``).

Batched multi-RHS execution: every op here is rank-polymorphic.  A plan
describes ``len(plan.dirs)`` grid dimensions; any leading axes of the array
are batch axes (``B`` independent right-hand sides sharing one plan), and a
direction's array axis is ``batch_ndim + p.dim``.  The 1-D transforms are
last-axis ops over flattened rows, so a batched solve runs the SAME number
of (bigger) FFT calls as a single solve -- the multi-RHS amortization of
the original FLUPS / P3DFFT batched transform APIs.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

__all__ = ["TransformEngine", "TransformSchedule", "as_engine",
           "build_schedule", "folded_normfact", "fwd_1d", "bwd_1d",
           "materialize_doubling", "crop_doubling", "ENGINES"]

ENGINES = ("xla", "pallas")


@dataclass(frozen=True)
class TransformEngine:
    """Execution backend selection for the transform + pointwise stages.

    ``interpret``: run Pallas kernels in interpret mode (CPU validation);
    on a real TPU runtime pass ``interpret=False`` to lower to Mosaic.
    """

    name: str = "xla"
    interpret: bool = True

    def __post_init__(self):
        if self.name not in ENGINES:
            raise ValueError(
                f"unknown engine {self.name!r}; expected one of {ENGINES}")

    @property
    def use_pallas(self) -> bool:
        return self.name == "pallas"


def as_engine(engine) -> TransformEngine:
    """Accept ``"xla"`` / ``"pallas"`` / TransformEngine / None."""
    if engine is None:
        return TransformEngine()
    if isinstance(engine, TransformEngine):
        return engine
    return TransformEngine(str(engine))


# ---------------------------------------------------------------------------
# per-direction 1-D ops (jnp, last-axis via moveaxis)
# ---------------------------------------------------------------------------

def _batch_ndim(x, sched) -> int:
    """Leading batch axes of ``x`` relative to the schedule's grid rank."""
    if sched is None or not sched.dirs:
        return 0
    bnd = x.ndim - len(sched.dirs)
    assert 0 <= bnd, (x.shape, len(sched.dirs))
    return bnd


def fwd_1d(x, p, sched=None):
    """Forward 1-D transform of direction ``p`` (a ``Plan1D``), applied to
    the whole block or to any chunk cut along an axis other than ``p.dim``.
    Leading batch axes (multi-RHS) pass through untouched -- the schedule
    is what knows the grid rank, so batched arrays REQUIRE ``sched``;
    with ``sched=None`` the array rank must equal the plan's.

    Valid-extent contract: the incoming axis carries ``p.valid_in`` live
    points (``n_pts`` deferred, ``n_fft`` when the plan pre-padded the
    Hockney doubling up front) and the outgoing axis carries ``p.n_out``.
    """
    # measured (EXPERIMENTS.md section Perf, flups cell): transforming along
    # the native axis (jnp.fft axis=d) REGRESSES bytes by 11% -- XLA
    # transposes internally for non-minor FFT axes and loses the fusion of
    # the explicit moveaxis (a no-op when d is already last). Keep moveaxis.
    from . import transforms as tr
    engine = sched.engine if sched is not None else None
    x = jnp.moveaxis(x, _batch_ndim(x, sched) + p.dim, -1)
    if p.pre_padded:
        # dense up-front doubling: the zero extension is already in the
        # array, the transform is a plain full-length one
        if p.category in ("sym", "semi"):
            raise AssertionError("pre_padded is a DFT-direction mode")
        y = tr._rfft(x, engine) if p.dft == "r2c" else tr._cfft(x, engine)
        return jnp.moveaxis(y, -1, _batch_ndim(y, sched) + p.dim)
    if p.flip:
        x = x[..., ::-1]
    x = x[..., p.in_start:p.in_start + p.n_in]
    if p.category in ("sym", "semi"):
        if p.n_fft > p.n_in:
            pad = [(0, 0)] * (x.ndim - 1) + [(0, p.n_fft - p.n_in)]
            x = jnp.pad(x, pad)
        tables = sched.fwd_tables[p.dim] if sched is not None else None
        y = tr.r2r_forward(x, p.kind, engine=engine, tables=tables)
    elif p.dft == "r2c":
        # pruned forward: the length-n_fft spectrum from the n_in nonzero
        # inputs (Pallas skips the zero tail; XLA pads -- bit-identical)
        y = tr._rfft_padded(x, p.n_fft, engine)
    else:
        y = tr._cfft_padded(x, p.n_fft, engine)
    return jnp.moveaxis(y, -1, _batch_ndim(y, sched) + p.dim)


def bwd_1d(y, p, sched=None):
    """Inverse 1-D transform of direction ``p``; chunk-safe like ``fwd_1d``
    (and like it, batched arrays require ``sched``).  Emits ``p.valid_in``
    points: only the ``n_in`` retained outputs under deferred doubling, the
    full ``n_fft`` reconstruction when the plan padded up front.
    """
    # NOTE: no normalization multiply here -- every direction's normfact is
    # folded into the Green's function at plan time (build_green).
    from . import transforms as tr
    engine = sched.engine if sched is not None else None
    y = jnp.moveaxis(y, _batch_ndim(y, sched) + p.dim, -1)
    if p.category in ("sym", "semi"):
        tables = sched.bwd_tables[p.dim] if sched is not None else None
        x = tr.r2r_backward(y, p.kind, engine=engine, tables=tables)
        x = x[..., :p.n_in]
    elif p.pre_padded:
        # dense mode keeps the doubled extent; cropped once at solve end
        x = (tr._irfft(y, p.n_fft, engine) if p.dft == "r2c"
             else tr._cfft(y, engine, inverse=True))
        return jnp.moveaxis(x, -1, _batch_ndim(x, sched) + p.dim)
    elif p.dft == "r2c":
        # pruned backward: reconstruct only the n_in retained samples
        x = tr._irfft_crop(y, p.n_fft, p.n_in, engine)
    else:
        x = tr._icfft_crop(y, p.n_in, engine)
    # place into the user-sized axis
    left = p.in_start
    right = p.n_pts - p.in_start - p.n_in - (1 if p.per_dup else 0)
    if left or right:
        pad = [(0, 0)] * (x.ndim - 1) + [(left, right)]
        x = jnp.pad(x, pad)
    if p.per_dup:  # node-periodic: duplicate the first point at the end
        x = jnp.concatenate([x, x[..., :1]], axis=-1)
    if p.flip:
        x = x[..., ::-1]
    return jnp.moveaxis(x, -1, _batch_ndim(x, sched) + p.dim)


def materialize_doubling(x, dirs):
    """Zero-pad every ``pre_padded`` direction of a user-shaped array from
    ``n_pts`` to ``n_fft`` (the dense up-front Hockney doubling; a no-op on
    deferred plans).  Leading batch axes pass through."""
    off = x.ndim - len(dirs)
    for d, p in enumerate(dirs):
        if p.pre_padded and x.shape[off + d] < p.n_fft:
            pad = [(0, 0)] * x.ndim
            pad[off + d] = (0, p.n_fft - x.shape[off + d])
            x = jnp.pad(x, pad)
    return x


def crop_doubling(x, dirs):
    """Crop every ``pre_padded`` direction back to its user extent (the
    final slice of a dense solve; a no-op on deferred plans)."""
    off = x.ndim - len(dirs)
    for d, p in enumerate(dirs):
        if p.pre_padded and x.shape[off + d] > p.n_pts:
            sl = [slice(None)] * x.ndim
            sl[off + d] = slice(0, p.n_pts)
            x = x[tuple(sl)]
    return x


@dataclass(frozen=True)
class TransformSchedule:
    """Plan-time constants for one solve: per-direction twiddle tables and
    the folded normalization (quadrature h weights stay in build_green)."""

    engine: TransformEngine
    fwd_tables: tuple    # per logical dim: twiddle dict for the forward kind
    bwd_tables: tuple    # per logical dim: twiddle dict for the inverse kind
    norm: float          # prod of r2r normfacts, folded into the Green
    dirs: tuple = ()     # per logical dim: the plan's Plan1D

    # -- fused transform+switch stage API (chunk-safe by construction) -----

    def fwd_chunk(self, x, d: int):
        """Forward 1-D transform of logical direction ``d`` on a full block
        or an uninvolved-axis chunk (the overlap strategy's stage unit)."""
        return fwd_1d(x, self.dirs[d], self)

    def bwd_chunk(self, x, d: int):
        """Inverse 1-D transform of logical direction ``d``; chunk-safe."""
        return bwd_1d(x, self.dirs[d], self)

    # live-extent bookkeeping lives on the plan: ``self.dirs[d].valid_in``
    # is the physical extent a topology switch ships for dim ``d`` (see
    # Plan1D; spectral extents are the plain ``n_out`` field)

    def green_multiply(self, yhat, green):
        """The fused pointwise pass (Green x normalization in one multiply)."""
        if self.engine.use_pallas:
            from repro.kernels import ops
            return ops.green_multiply(yhat, green,
                                      interpret=self.engine.interpret)
        if jnp.iscomplexobj(yhat):
            return yhat * green
        return yhat * green.astype(yhat.dtype)


def folded_normfact(plan) -> float:
    """The combined backward normalization of a plan -- the single factor
    ``build_green`` folds into the Green's function (every direction, DFT
    included; their normfact is 1.0)."""
    norm = 1.0
    for p in plan.dirs:
        norm *= p.normfact
    return norm


def build_schedule(plan, engine=None) -> TransformSchedule:
    """Compile a ``PoissonPlan`` into its per-direction transform schedule."""
    from . import transforms as tr
    from .bc import INVERSE_KIND

    engine = as_engine(engine)
    fwd, bwd = [], []
    for p in plan.dirs:
        if p.kind is None:       # DFT direction: no r2r twiddles
            fwd.append(None)
            bwd.append(None)
        else:
            fwd.append(tr.twiddle_tables(p.kind, p.n_fft))
            bwd.append(tr.twiddle_tables(INVERSE_KIND[p.kind], p.n_fft))
    return TransformSchedule(engine, tuple(fwd), tuple(bwd),
                             folded_normfact(plan), plan.dirs)
