"""Green's functions / kernels of the paper (section IV naming).

All construction happens in float64 numpy at plan time (it is a one-off
setup cost, exactly like flups' Green setup); the solver then carries the
transformed kernel as a device constant.

Families, by the number of unbounded-ish directions (fully unbounded or
semi-unbounded both count -- they share the doubled-domain physical kernel):

* 0 unbounded ("fully spectral"): diagonal symbol  Ghat = -s(|w|) / |w|^2
  - CHAT2 : s = 1                        (spectral-exact, paper Fig 6)
  - LGF2  : Ghat = -1 / sigma_h(w)        (2nd-order FD symbol)
  - HEJm  : s = gamma_m(|w| eps)          (order-m Gaussian regularization)
* 3 unbounded: radial physical kernels on the doubled grid
  - CHAT2 : -1/(4 pi r), cell-averaged at r=0 (2nd order)
  - LGF2  : lattice Green's function (Bessel-integral near field +
            -1/(4 pi r) far field)
  - HEJm  : -theta_m(r/eps) / (4 pi r), Gaussian-regularized (order m)
  - HEJ0  : -Si(pi r / h) / (2 pi^2 r)  (sharp spectral truncation)
* 2 unbounded + 1 spectral: screened 2-D kernels per mode kz
  - CHAT2 : -K0(|kz| r)/(2 pi)  (kz != 0),  log(r)/(2 pi)  (kz = 0),
            cell-averaged at r=0
  - HEJm  : Hankel-quadrature of gamma_m(|k| eps)/|k|^2 (tabulated radial)
* 1 unbounded + 2 spectral: -exp(-|kp| |x|)/(2 |kp|),  |x|/2 at kp = 0

gamma_m(s) = exp(-s^2/2) * sum_{j<m/2} (s^2/2)^j / j!   (m-moment Gaussian)
theta_m derived from gamma_m by the radial -lap recurrence
P_{j+1} = -(P_j'' - 2 rho P_j' + (rho^2 - 1) P_j), P_1 = rho  (see tests).
"""
from __future__ import annotations

import numpy as np
from scipy import special as sp

__all__ = ["GreenKind", "spectral_symbol", "kernel_3unb", "kernel_2unb_batch",
           "kernel_1unb", "HEJ_ORDERS", "hej_theta", "lgf3_table"]

HEJ_ORDERS = (2, 4, 6, 8, 10)
_INV4PI = 1.0 / (4.0 * np.pi)
# mean of 1/|r| over the unit cube (self-cell average for CHAT2, 3D)
_CUBE_AVG_1OR = 2.3800774834429582
# mean of ln|r| over the unit square (self-cell average, 2D)
_SQ_AVG_LNR = -1.6108527503878035


class GreenKind:
    CHAT2 = "chat2"
    LGF2 = "lgf2"
    HEJ0 = "hej0"
    HEJ2 = "hej2"
    HEJ4 = "hej4"
    HEJ6 = "hej6"
    HEJ8 = "hej8"
    HEJ10 = "hej10"

    ALL = (CHAT2, LGF2, HEJ0, HEJ2, HEJ4, HEJ6, HEJ8, HEJ10)

    @staticmethod
    def hej_order(kind: str) -> int | None:
        if kind.startswith("hej"):
            return int(kind[3:])
        return None


def _gamma_m(s: np.ndarray, m: int) -> np.ndarray:
    """Order-m Gaussian regularization factor gamma_m(s) = e^{-s^2/2} T_{m/2-1}(s^2/2)."""
    half = s * s / 2.0
    acc = np.zeros_like(s)
    term = np.ones_like(s)
    for j in range(m // 2):
        if j > 0:
            term = term * half / j
        acc = acc + term
    return np.exp(-half) * acc


def _hej_poly_coeffs(m: int) -> list[np.poly1d]:
    """P_j polynomials of the radial recurrence, j = 1 .. m/2 - 1."""
    polys = []
    p = np.poly1d([1.0, 0.0])  # P_1 = rho
    polys.append(p)
    for _ in range(m // 2 - 2):
        rho = np.poly1d([1.0, 0.0])
        pp = p.deriv()
        ppp = pp.deriv()
        p = -(ppp - 2 * rho * pp + (rho * rho - 1) * p)
        polys.append(p)
    return polys


def hej_theta(rho: np.ndarray, m: int) -> np.ndarray:
    """theta_m(rho): G_m(r) = -theta_m(r/eps) / (4 pi r)."""
    base = sp.erf(rho / np.sqrt(2.0))
    if m == 2:
        return base
    corr = np.zeros_like(rho)
    fact = 1.0
    for j, poly in enumerate(_hej_poly_coeffs(m), start=1):
        fact *= 2.0 * j  # (2^j j!)
        corr = corr + np.polyval(poly.coeffs, rho) / fact
    return base + np.sqrt(2.0 / np.pi) * np.exp(-rho * rho / 2.0) * corr


# ---------------------------------------------------------------------------
# fully spectral symbol
# ---------------------------------------------------------------------------

def spectral_symbol(kind: str, w2_sum: np.ndarray, h: float,
                    w_axes: list[np.ndarray] | None = None,
                    eps_factor: float = 2.0) -> np.ndarray:
    """Ghat on the fully-spectral mode grid. ``w2_sum`` = |omega|^2 grid."""
    out = np.zeros_like(w2_sum)
    nz = w2_sum > 1e-14
    if kind == GreenKind.CHAT2 or kind == GreenKind.HEJ0:
        out[nz] = -1.0 / w2_sum[nz]
    elif kind == GreenKind.LGF2:
        assert w_axes is not None
        sig = np.zeros_like(w2_sum)
        for ax, w in enumerate(w_axes):
            shape = [1] * w2_sum.ndim
            shape[ax] = w.size
            sig = sig + (2.0 - 2.0 * np.cos(w.reshape(shape) * h)) / (h * h)
        nzs = sig > 1e-14
        out[nzs] = -1.0 / sig[nzs]
    else:
        m = GreenKind.hej_order(kind)
        eps = eps_factor * h
        out[nz] = -_gamma_m(np.sqrt(w2_sum[nz]) * eps, m) / w2_sum[nz]
    return out


# ---------------------------------------------------------------------------
# 3 unbounded directions: radial kernels
# ---------------------------------------------------------------------------

def lgf3_table(nmax: int, t_break: float = 2.0,
               t_max: float = 1.0e5) -> np.ndarray:
    """LGF of the 7-point Laplacian, G(n) = -int_0^inf prod_i ive(n_i, 2t) dt.

    Returns table[n1, n2, n3] for 0 <= n_i <= nmax (dimensionless; the
    physical kernel is table / h).  Composite Gauss-Legendre quadrature
    ([0, t_break] linear + [t_break, t_max] log-substituted) plus the
    two-term (4 pi t)^{-3/2} (1 - a/t) asymptotic tail -> ~1e-10 absolute.
    """
    q, w = np.polynomial.legendre.leggauss(48)
    ts, ws = [], []
    # linear panels on [0, t_break]
    for lo, hi in zip(np.linspace(0.0, t_break, 5)[:-1],
                      np.linspace(0.0, t_break, 5)[1:]):
        ts.append(0.5 * (hi - lo) * (q + 1.0) + lo)
        ws.append(0.5 * (hi - lo) * w)
    # log panels on [t_break, t_max]
    taus = np.linspace(np.log(t_break), np.log(t_max), 13)
    for lo, hi in zip(taus[:-1], taus[1:]):
        tau = 0.5 * (hi - lo) * (q + 1.0) + lo
        ts.append(np.exp(tau))
        ws.append(0.5 * (hi - lo) * w * np.exp(tau))  # dt = e^tau dtau
    t = np.concatenate(ts)
    wt = np.concatenate(ws)
    ive = np.stack([sp.ive(n, 2.0 * t) for n in range(nmax + 1)])  # (n, t)
    integral = np.einsum("at,bt,ct,t->abc", ive, ive, ive, wt)
    # two-term tail: prod ~ (4 pi t)^{-3/2} (1 - a / t), a = sum(4 n_i^2 - 1)/16
    n = np.arange(nmax + 1)
    a = ((4 * n[:, None, None] ** 2 - 1) + (4 * n[None, :, None] ** 2 - 1)
         + (4 * n[None, None, :] ** 2 - 1)) / 16.0
    tail = (4.0 * np.pi) ** -1.5 * (
        2.0 / np.sqrt(t_max) - a * (2.0 / 3.0) / t_max ** 1.5)
    return -(integral + tail)


def kernel_3unb(kind: str, r: np.ndarray, h: float,
                eps_factor: float = 2.0,
                lgf_cutoff: int = 32) -> np.ndarray:
    """Radial kernel sampled at distances ``r`` (r may contain 0)."""
    rs = np.where(r > 0, r, 1.0)
    if kind == GreenKind.CHAT2:
        g = -_INV4PI / rs
        g = np.where(r > 0, g, -_INV4PI * _CUBE_AVG_1OR / h)
        return g
    if kind == GreenKind.HEJ0:
        si, _ = sp.sici(np.pi * rs / h)
        g = -si / (2.0 * np.pi ** 2 * rs)
        return np.where(r > 0, g, -1.0 / (2.0 * np.pi * h))
    if kind == GreenKind.LGF2:
        # handled on the integer lattice by the caller via lgf3_table;
        # generic fallback: far-field
        return np.where(r > 0, -_INV4PI / rs, -0.25273100985866 / h)
    m = GreenKind.hej_order(kind)
    eps = eps_factor * h
    rho = rs / eps
    g = -_INV4PI * hej_theta(rho, m) / rs
    # theta_m(rho) ~ sqrt(2/pi) rho (1 + sum 1/(2^j j!) P_j(0)') as rho->0;
    # limit of theta/rho:
    lim = np.sqrt(2.0 / np.pi)
    if m > 2:
        fact = 1.0
        extra = 0.0
        for j, poly in enumerate(_hej_poly_coeffs(m), start=1):
            fact *= 2.0 * j
            extra += np.polyval(poly.deriv().coeffs, 0.0) / fact
        lim = np.sqrt(2.0 / np.pi) * (1.0 + extra)
    return np.where(r > 0, g, -_INV4PI * lim / eps)


def lgf3_on_grid(dist_idx: tuple[np.ndarray, np.ndarray, np.ndarray],
                 h: float, cutoff: int = 24) -> np.ndarray:
    """LGF2 kernel on integer offsets (|i|,|j|,|k|) with near/far split."""
    i, j, k = dist_idx
    nmax_needed = int(max(i.max(), j.max(), k.max()))
    near_max = min(cutoff, nmax_needed)
    table = lgf3_table(near_max)
    r2 = i * i + j * j + k * k
    r = np.sqrt(np.maximum(r2, 1e-300))
    far = -_INV4PI / np.where(r > 0, r, 1.0)
    use_near = (i <= near_max) & (j <= near_max) & (k <= near_max)
    ii = np.minimum(i, near_max)
    jj = np.minimum(j, near_max)
    kk = np.minimum(k, near_max)
    near = table[ii, jj, kk]
    g = np.where(use_near, near, far)
    return g / h


# ---------------------------------------------------------------------------
# 2 unbounded + 1 spectral: screened 2-D kernels
# ---------------------------------------------------------------------------

def _k0_cell_avg(a: float, h: float, nq: int = 24) -> float:
    """Cell average of K0(a r) over the h x h cell at the origin."""
    q, wq = np.polynomial.legendre.leggauss(nq)
    x = 0.5 * h * (q + 1.0) / 2.0 + 0.0  # [0, h/2]
    x = 0.25 * h * (q + 1.0)
    wx = 0.25 * h * wq
    xx, yy = np.meshgrid(x, x, indexing="ij")
    ww = np.outer(wx, wx)
    rr = np.hypot(xx, yy)
    val = (sp.k0(a * rr) * ww).sum() * 4.0 / (h * h)
    return float(val)


def kernel_2unb_batch(kind: str, kzs: np.ndarray, r: np.ndarray, h: float,
                      eps_factor: float = 2.0) -> np.ndarray:
    """Mixed-space kernels, radial in the 2 unbounded directions, for ALL
    spectral modes ``kzs`` at once -> shape (len(kzs),) + r.shape.

    CHAT2/LGF2 closed forms; HEJ family via a shared radial Hankel
    quadrature table (the J0(k r) matrix is reused across modes)."""
    kzs = np.atleast_1d(np.asarray(kzs, dtype=np.float64))
    out = np.empty((kzs.size,) + r.shape, dtype=np.float64)
    rs = np.where(r > 0, r, 1.0)
    if kind in (GreenKind.CHAT2, GreenKind.LGF2):
        # LGF2 falls back to CHAT2 in mixed regimes (2nd order either way)
        for i, kz in enumerate(kzs):
            if abs(kz) < 1e-14:
                g = np.log(rs) / (2.0 * np.pi)
                g0 = (np.log(h) + _SQ_AVG_LNR) / (2.0 * np.pi)
            else:
                g = -sp.k0(np.abs(kz) * rs) / (2.0 * np.pi)
                g0 = -_k0_cell_avg(abs(kz), h) / (2.0 * np.pi)
            out[i] = np.where(r > 0, g, g0)
        return out
    # HEJ family (incl. HEJ0): kz = 0 closed form, kz != 0 Hankel quadrature
    m = GreenKind.hej_order(kind)
    eps = eps_factor * h
    kmax = 16.0 / eps if m != 0 else np.pi / h
    rmax = float(r.max()) if r.size else 1.0
    # enough k samples to resolve J0(k r) oscillations at rmax
    nk = int(max(4096, kmax * max(rmax, h) / 0.25))
    kgrid = np.linspace(0.0, kmax, nk + 1)[1:]
    rtab = np.linspace(0.0, max(rmax, h), 2048)
    j0 = sp.j0(np.outer(kgrid, rtab))              # (nk, ntab), shared
    for i, kz in enumerate(kzs):
        if abs(kz) < 1e-14:
            if m == 0:
                # sharp spectral truncation: quadrature + gauge to ln(r)/2pi
                # (bounded to 2nd order, as the paper notes for HEJ0 here)
                wgt = -kgrid / (kgrid ** 2)
                gtab = np.trapezoid(wgt[:, None] * j0, kgrid,
                                    axis=0) / (2.0 * np.pi)
                gtab = gtab - gtab[-1] + np.log(rtab[-1]) / (2.0 * np.pi)
                out[i] = np.interp(r, rtab, gtab)
            else:
                out[i] = _hej_2d_closed(r, eps, m)
            continue
        if m == 0:
            gam = np.ones_like(kgrid)              # sharp truncation at pi/h
        else:
            gam = _gamma_m(np.sqrt(kgrid ** 2 + kz ** 2) * eps, m)
        wgt = -(gam / (kgrid ** 2 + kz ** 2) * kgrid)
        gtab = np.trapezoid(wgt[:, None] * j0, kgrid, axis=0) / (2.0 * np.pi)
        out[i] = np.interp(r, rtab, gtab)
    return out


def _hej_2d_poly(m: int) -> list[np.poly1d]:
    """Q_j polynomials of the 2-D radial recurrence, j = 1 .. m/2 - 1:
    Q_1 = -1,  Q_{j+1} = Q'' + Q'/rho - 2 rho Q' + (rho^2 - 2) Q."""
    rho = np.poly1d([1.0, 0.0])
    q = np.poly1d([-1.0])
    out = [q]
    for _ in range(m // 2 - 2):
        dq = q.deriv()
        # Q'/rho is polynomial: all our Q are even, so dq has zero constant
        dq_over, rem = np.polydiv(dq, rho)
        assert np.allclose(rem, 0.0)
        q = q.deriv().deriv() + np.poly1d(dq_over) - 2 * rho * dq + \
            (rho * rho - 2) * q
        out.append(q)
    return out


def _hej_2d_closed(r: np.ndarray, eps: float, m: int) -> np.ndarray:
    """2-D Gaussian-regularized kernel, closed form:
    G_m = (1/2pi)[ln r + E1(rho^2/2)/2 + e^{-rho^2/2} sum Q_j(rho)/(2^j j!)]."""
    rs = np.where(r > 0, r, 1.0)
    rho = rs / eps
    val = np.log(rs) + 0.5 * sp.exp1(rho * rho / 2.0)
    if m > 2:
        corr = np.zeros_like(rho)
        fact = 1.0
        for j, poly in enumerate(_hej_2d_poly(m), start=1):
            fact *= 2.0 * j
            corr = corr + np.polyval(poly.coeffs, rho) / fact
        val = val + np.exp(-rho * rho / 2.0) * corr
    # r -> 0 limit: ln r + E1/2 -> (ln(2 eps^2) - gamma_E)/2 ... finite
    gamma_e = 0.5772156649015329
    lim = 0.5 * (np.log(2.0 * eps * eps) - gamma_e)
    if m > 2:
        corr0 = 0.0
        fact = 1.0
        for j, poly in enumerate(_hej_2d_poly(m), start=1):
            fact *= 2.0 * j
            corr0 += np.polyval(poly.coeffs, 0.0) / fact
        lim = lim + corr0
    return np.where(r > 0, val, lim) / (2.0 * np.pi)


# ---------------------------------------------------------------------------
# 1 unbounded + 2 spectral
# ---------------------------------------------------------------------------

def kernel_1unb(kind: str, kperp2: float, x: np.ndarray, h: float,
                eps_factor: float = 2.0) -> np.ndarray:
    """Mixed-space kernel: 2 spectral modes (|kperp|^2 given), 1 physical dir."""
    kp = np.sqrt(kperp2)
    ax = np.abs(x)
    if kp < 1e-14:
        return ax / 2.0  # 1-D kernel: G = |x|/2 (d^2/dx^2 G = delta)
    return -np.exp(-kp * ax) / (2.0 * kp)
