"""Load-balanced, invertible distribution of unknowns (paper Appendix A).

Distributes ``N`` unknowns over ``P`` ranks such that

* every rank owns at least ``B = N // P`` unknowns (the baseline),
* the ``R = N % P`` excess unknowns are spread over the whole rank range in
  ``R`` groups of stride ``S = P // R`` (the *last* rank of each group gets
  one extra), instead of piling up on the first ``R`` ranks,
* both directions are O(1) closed forms:
  ``rank -> first index`` (eq. 24)  and ``index -> rank`` (eqs. 25-).

This is what makes node-centered layouts (N+1 points on an even rank count)
load balanced across *nodes* and not only across ranks.

Also provides the congestion-avoiding send ordering of Appendix A.1: rank r
communicates with r+1, r+2, ... (rotated), never everyone-hits-rank-0.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "rank_first_index", "rank_count", "index_to_rank",
    "counts", "send_order",
]


def _bsr(n: int, p: int):
    b = n // p
    r = n % p
    s = p // r if r > 0 else p
    return b, r, s


def rank_first_index(n: int, p: int, rank) -> int:
    """First global index owned by ``rank`` (paper eq. 24)."""
    b, r, s = _bsr(n, p)
    rank = np.asarray(rank)
    return rank * b + np.minimum(rank // s, r)


def rank_count(n: int, p: int, rank) -> int:
    """Number of unknowns owned by ``rank``."""
    return rank_first_index(n, p, np.asarray(rank) + 1) - rank_first_index(
        n, p, rank)


def index_to_rank(n: int, p: int, idx) -> int:
    """Owning rank of global index ``idx`` (paper eqs. 25-)."""
    b, r, s = _bsr(n, p)
    idx = np.asarray(idx)
    if r == 0:
        return idx // b
    if b == 0:
        # one datum per group, owned by the group's last rank
        return idx * s + (s - 1)
    group = np.minimum(idx // (s * b + 1), r)          # eq. 25
    local = idx - group * (s * b + 1)                  # local data index
    local_rank = local // b
    # bound to S-1 inside full groups (the +1 data sits on the group's last rank)
    local_rank = np.where(group < r, np.minimum(local_rank, s - 1), local_rank)
    return group * s + local_rank


def counts(n: int, p: int) -> np.ndarray:
    """Per-rank counts, shape (p,)."""
    ranks = np.arange(p + 1)
    starts = rank_first_index(n, p, ranks)
    return np.diff(starts)


def send_order(p: int, rank: int) -> np.ndarray:
    """Destination ordering for rank ``rank`` (Appendix A.1).

    Rank r sends first to r+1, then r+2, ... wrapping around, so that send
    requests are spread over receivers instead of all hitting rank 0 first.
    """
    return (rank + 1 + np.arange(p)) % p
