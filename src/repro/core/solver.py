"""FFT-based Poisson solver (the flups pipeline), single-process reference.

The solve is the paper's algorithm:

  forward:  for each direction (r2r dirs first, then semi-unbounded r2r,
            then the DFT dirs -- the first DFT dir is real-to-complex):
            shuffle the direction to the last axis, pad / slice per the BC
            convention (section II), 1-D transform;
  multiply: pointwise with the transformed Green's function (+ quadrature
            weight h per unbounded-ish direction and the r2r normalization);
  backward: inverse transforms in reverse order, crop, write back the
            convention-overwritten boundary values.

The distributed version (``repro.core.comm`` + ``repro.distributed``) swaps
the axis shuffles for pencil topology switches; the per-direction math here
is reused unchanged.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
import scipy.fft as sfft

from .bc import (BCType, DataLayout, DirBC, TransformKind, r2r_kind,
                 INVERSE_KIND)
from . import transforms as tr
from . import green as gr
from .engine import (RELAYOUT_MODES, as_engine, build_schedule,
                     folded_normfact, fwd_1d, bwd_1d, relayout as _relayout,
                     schedule_layouts)

__all__ = ["Plan1D", "PoissonPlan", "PoissonSolver", "make_plan",
           "get_solver", "clear_solver_cache", "solver_cache_info",
           "set_solver_cache_capacity", "evict_solver_entries",
           "evict_solver_instance"]


@dataclass(frozen=True)
class Plan1D:
    dim: int
    bc: DirBC
    layout: DataLayout
    n: int                  # number of cells; node layout owns n+1 points
    L: float
    category: str           # "sym" | "semi" | "per" | "unb"
    kind: TransformKind | None
    dft: str | None         # "r2c" | "c2c" | None
    n_pts: int              # points in the user array along this dim
    in_start: int           # first user point handed to the transform
    n_in: int               # number of user points handed to the transform
    n_fft: int              # transform length (after padding)
    n_out: int              # spectral storage size
    flip: bool
    koffset: int            # storage index -> mode index offset
    normfact: float
    modes: tuple            # omega per storage index (length n_out)
    zero_left: bool = False   # backward writes 0 at user index 0
    zero_right: bool = False  # backward writes 0 at the last user index
    per_dup: bool = False     # node-periodic: copy u_0 into u_N
    # Hockney-doubling execution mode of this direction (PoissonPlan
    # ``doubling``): False = deferred/pruned (default; the transform pads
    # n_in -> n_fft itself, so every stage before it sees only the n_in
    # live points), True = the zero extension is materialized UP FRONT in
    # the user array (dense textbook Hockney: transforms and topology
    # switches all see the doubled extent).
    pre_padded: bool = False

    @property
    def h(self) -> float:
        return self.L / self.n

    @property
    def is_unbounded_like(self) -> bool:
        return self.category in ("semi", "unb")

    @property
    def valid_in(self) -> int:
        """Live physical extent of this axis anywhere OUTSIDE the 1-D
        transform: what the solvers carry through topology switches before
        the forward and after the backward transform of this direction
        (the spectral counterpart is the plain ``n_out`` field)."""
        return self.n_fft if self.pre_padded else self.n_pts


def _sym_plan(dim, bc, layout, n, L) -> Plan1D:
    kind = r2r_kind(bc, layout)
    h = L / n
    if layout == DataLayout.NODE:
        n_pts = n + 1
        table = {
            TransformKind.DST1: (1, n - 1, True, True),
            TransformKind.DST3: (1, n, True, False),
            TransformKind.DCT3: (0, n, False, True),
            TransformKind.DCT1: (0, n + 1, False, False),
        }
        in_start, n_in, zl, zr = table[kind]
    else:
        n_pts, in_start, n_in, zl, zr = n, 0, n, False, False
    half = kind in (TransformKind.DCT3, TransformKind.DCT4,
                    TransformKind.DST3, TransformKind.DST4)
    koff = 1 if kind in (TransformKind.DST1, TransformKind.DST2) else 0
    k = np.arange(n_in) + koff
    modes = (k + 0.5) * np.pi / L if half else k * np.pi / L
    return Plan1D(dim, bc, layout, n, L, "sym", kind, None, n_pts,
                  in_start, n_in, n_in, n_in, False, koff,
                  tr.r2r_normfact(kind, n_in), tuple(modes), zl, zr)


def _per_plan(dim, bc, layout, n, L, dft) -> Plan1D:
    n_pts = n + 1 if layout == DataLayout.NODE else n
    if dft == "r2c":
        n_out = n // 2 + 1
        modes = 2.0 * np.pi * np.arange(n_out) / L
    else:
        n_out = n
        modes = 2.0 * np.pi * np.fft.fftfreq(n) * n / L
    return Plan1D(dim, bc, layout, n, L, "per", None, dft, n_pts, 0, n, n,
                  n_out, False, 0, 1.0, tuple(modes),
                  per_dup=(layout == DataLayout.NODE))


def _unb_plan(dim, bc, layout, n, L, dft) -> Plan1D:
    n_pts = n + 1 if layout == DataLayout.NODE else n
    n_in = n_pts
    n_fft = 2 * n
    if dft == "r2c":
        n_out = n + 1
        modes = 2.0 * np.pi * np.arange(n_out) / (2.0 * L)
    else:
        n_out = n_fft
        modes = 2.0 * np.pi * np.fft.fftfreq(n_fft) * n_fft / (2.0 * L)
    return Plan1D(dim, bc, layout, n, L, "unb", None, dft, n_pts, 0, n_in,
                  n_fft, n_out, False, 0, 1.0, tuple(modes))


def _semi_plan(dim, bc, layout, n, L) -> Plan1D:
    """Semi-unbounded: doubled domain + same-symmetry r2r at both ends.

    The rhs support [0, L] inside the 2L transform domain makes the far-end
    image exact (Hockney doubling, see tests/test_poisson.py oracle).
    """
    flip = bc.right != BCType.UNB          # symmetry end on the right
    sym = bc.right if flip else bc.left
    pair = DirBC(sym, sym)
    kind = r2r_kind(pair, layout)          # on the doubled domain
    if layout == DataLayout.NODE:
        n_pts = n + 1
        if kind == TransformKind.DST1:     # odd: interior of doubled domain
            in_start, n_in, n_fft = 1, n, 2 * n - 1
            zl, zr = True, False
        else:                              # DCT1 on 2n+1 points
            in_start, n_in, n_fft = 0, n + 1, 2 * n + 1
            zl = zr = False
    else:
        n_pts, in_start, n_in, n_fft = n, 0, n, 2 * n
        zl = zr = False
    koff = 1 if kind in (TransformKind.DST1, TransformKind.DST2) else 0
    modes = (np.arange(n_fft) + koff) * np.pi / (2.0 * L)
    return Plan1D(dim, bc, layout, n, L, "semi", kind, None, n_pts,
                  in_start, n_in, n_fft, n_fft, flip, koff,
                  tr.r2r_normfact(kind, n_fft), tuple(modes), zl, zr)


DOUBLING_MODES = ("deferred", "upfront")
ORDER_POLICIES = ("layout", "natural")


def _choose_order(groups, ndim: int, policy: str):
    """Execution order of the dims, grouped by BC category (sym, then
    semi, then DFT -- the grouping is a correctness constraint; the order
    WITHIN each group is free).

    ``policy="natural"`` keeps the historical ascending order.
    ``policy="layout"`` (default) picks, among all grouping-consistent
    orders, the one whose ``schedule_layouts`` needs the fewest edge
    relayouts -- e.g. single-category plans run ``(2, 0, 1)``, which both
    starts AND ends the layout-scheduled pipeline in the user's natural
    layout, so the only transposes left are the ones fused into the
    topology switches.  Ties break to the lexicographically smallest
    order, so mixed-BC plans keep their historical order and results.
    """
    if policy == "natural":
        return tuple(d for g in groups for d in g)
    from itertools import permutations, product
    nat = tuple(range(ndim))
    best = None
    for combo in product(*[tuple(permutations(g)) for g in groups]):
        order = tuple(d for g in combo for d in g)
        lay = schedule_layouts(order, ndim)
        cost = int(lay.fwd[0] != nat) + int(lay.bwd[-1] != nat)
        if best is None or (cost, order) < best:
            best = (cost, order)
    return best[1]


@dataclass(frozen=True)
class PoissonPlan:
    dirs: tuple            # Plan1D per logical dim (0..2)
    order: tuple           # execution order of dims (forward)
    green_kind: str
    eps_factor: float
    # Hockney-doubling placement for the fully-unbounded directions:
    #   "deferred" (default) -- pruned execution: the length-2n zero
    #       extension exists only inside that direction's own 1-D transform,
    #       so every other stage (other-direction transforms, topology
    #       switches) sees the n live points;
    #   "upfront"  -- dense textbook Hockney: the input field is padded to
    #       2n in every unbounded direction before the first transform (the
    #       bench_solve baseline; spectral storage is identical either way).
    doubling: str = "deferred"

    @property
    def input_shape(self):
        return tuple(p.n_pts for p in self.dirs)


def make_plan(shape, L, bcs, layout=DataLayout.CELL,
              green_kind=gr.GreenKind.CHAT2, eps_factor=2.0,
              doubling: str = "deferred",
              order_policy: str = "layout") -> PoissonPlan:
    """``shape`` = cells per dim; ``bcs`` = 3 (left,right) BCType pairs."""
    assert doubling in DOUBLING_MODES, doubling
    assert order_policy in ORDER_POLICIES, order_policy
    ndim = len(shape)
    bcs = tuple(DirBC(*b) if not isinstance(b, DirBC) else b for b in bcs)
    for b in bcs:
        b.validate()
    sym_dims, semi_dims, dft_dims = [], [], []
    for d, b in enumerate(bcs):
        if b.is_unbounded or b.is_periodic:
            dft_dims.append(d)
        elif b.is_semi_unbounded:
            semi_dims.append(d)
        else:
            sym_dims.append(d)
    order = _choose_order([g for g in (sym_dims, semi_dims, dft_dims) if g],
                          ndim, order_policy)
    plans = [None] * ndim
    # the real-to-complex direction is the first DFT direction the solve
    # EXECUTES (order-dependent: everything before it is real r2r)
    first_dft = next((d for d in order if d in dft_dims), None)
    for d, b in enumerate(bcs):
        Ld = L[d] if isinstance(L, (tuple, list)) else L
        if b.is_periodic:
            dft = "r2c" if d == first_dft else "c2c"
            plans[d] = _per_plan(d, b, layout, shape[d], Ld, dft)
        elif b.is_unbounded:
            dft = "r2c" if d == first_dft else "c2c"
            plans[d] = _unb_plan(d, b, layout, shape[d], Ld, dft)
        elif b.is_semi_unbounded:
            plans[d] = _semi_plan(d, b, layout, shape[d], Ld)
        else:
            plans[d] = _sym_plan(d, b, layout, shape[d], Ld)
    if doubling == "upfront":
        import dataclasses as _dc
        # dense Hockney applies to the fully-unbounded dirs only (semi dirs
        # keep their r2r in_start/flip slicing, sym/per dirs never pad), so
        # periodic-only plans are bit-identical across both modes
        plans = [_dc.replace(p, pre_padded=True) if p.category == "unb"
                 else p for p in plans]
    return PoissonPlan(tuple(plans), order, green_kind, eps_factor, doubling)


# ---------------------------------------------------------------------------
# Green's function assembly (numpy, plan time)
# ---------------------------------------------------------------------------

def _green_phys_coord(p: Plan1D) -> np.ndarray:
    """Physical sample offsets (units of h index) for an unbounded-ish dir."""
    if p.category == "unb":
        j = np.arange(p.n_fft)
        return np.minimum(j, p.n_fft - j).astype(np.float64)
    # semi: node-sampled kernel on [0, 2L]: DCT-I grid with 2n+1 points
    return np.arange(2 * p.n + 1, dtype=np.float64)


def _green_dct1_align(gh: np.ndarray, axis: int, p: Plan1D) -> np.ndarray:
    """DCT-I transform of the kernel along a semi dir + koffset alignment."""
    gh = sfft.dct(gh, type=1, axis=axis, norm=None)
    sl = [slice(None)] * gh.ndim
    sl[axis] = slice(p.koffset, p.koffset + p.n_out)
    return gh[tuple(sl)]


def build_green(plan: PoissonPlan) -> np.ndarray:
    """Transformed Green's function aligned with the rhs spectral storage.

    The combined normalization of every backward r2r transform (the product
    of the per-direction ``normfact``) is folded in HERE, once at plan time:
    the backward pass then runs unnormalized transforms and the solve
    performs a single pointwise multiply total (see ``TransformSchedule``).
    """
    dirs = plan.dirs
    norm = folded_normfact(plan)
    unb = [p for p in dirs if p.is_unbounded_like]
    spec = [p for p in dirs if not p.is_unbounded_like]
    n_unb = len(unb)
    kind = plan.green_kind
    hs = [p.h for p in dirs]
    h_ref = float(np.min([p.h for p in unb])) if unb else float(np.min(hs))

    if n_unb == 0:
        w = [np.asarray(p.modes) for p in dirs]
        grids = np.meshgrid(*w, indexing="ij")
        w2 = sum(g * g for g in grids)
        gh = gr.spectral_symbol(kind, w2, h_ref, w_axes=w,
                                eps_factor=plan.eps_factor)
        return gh * norm

    # physical axes for unbounded-ish dirs, mode axes for spectral dirs
    axes_coord = []
    for p in dirs:
        if p.is_unbounded_like:
            axes_coord.append(("phys", _green_phys_coord(p) * p.h))
        else:
            axes_coord.append(("mode", np.asarray(p.modes)))
    shape = tuple(len(c[1]) for c in axes_coord)
    g = np.zeros(shape, dtype=np.float64)

    phys_dims = [d for d, p in enumerate(dirs) if p.is_unbounded_like]
    mode_dims = [d for d, p in enumerate(dirs) if not p.is_unbounded_like]

    def bcast(arr1d, d):
        sh = [1] * len(dirs)
        sh[d] = len(arr1d)
        return np.asarray(arr1d).reshape(sh)

    if n_unb == 3:
        if kind == gr.GreenKind.LGF2:
            idx = [np.abs(np.rint(axes_coord[d][1] / dirs[d].h)).astype(int)
                   for d in range(3)]
            ii = [bcast(ix, d) for d, ix in enumerate(idx)]
            ii = np.broadcast_arrays(*ii)
            g = gr.lgf3_on_grid(tuple(ii), h_ref)
        else:
            r2 = sum(bcast(axes_coord[d][1], d) ** 2 for d in range(3))
            g = gr.kernel_3unb(kind, np.sqrt(r2), h_ref,
                               eps_factor=plan.eps_factor)
    elif n_unb == 2:
        (dm,) = mode_dims
        modes = np.asarray(axes_coord[dm][1])
        r2 = sum(bcast(axes_coord[d][1], d) ** 2 for d in phys_dims)
        r = np.sqrt(np.squeeze(r2, axis=dm))          # (n1, n2) radial grid
        gk = gr.kernel_2unb_batch(kind, modes, r, h_ref,
                                  eps_factor=plan.eps_factor)  # (nkz, n1, n2)
        g = np.moveaxis(gk, 0, dm)
    elif n_unb == 1:
        (dp,) = phys_dims
        x = axes_coord[dp][1]
        g = np.zeros(shape)
        # generic: iterate over mode combinations (cheap: O(N^2) combos)
        it = np.ndindex(*[shape[d] if d != dp else 1 for d in range(len(dirs))])
        for idx in it:
            kperp2 = 0.0
            for d in mode_dims:
                kperp2 += axes_coord[d][1][idx[d]] ** 2
            sl = list(idx)
            sl[dp] = slice(None)
            g[tuple(sl)] = gr.kernel_1unb(kind, kperp2, x, h_ref,
                                          eps_factor=plan.eps_factor)
    else:
        raise AssertionError

    # quadrature weight: h per unbounded-ish direction
    for d in phys_dims:
        g = g * dirs[d].h

    # transform along unbounded-ish dirs
    for d in phys_dims:
        p = dirs[d]
        if p.category == "unb":
            gh = np.fft.fft(g, axis=d)
            g = gh.real  # kernel is even-symmetric -> real spectrum
            if p.dft == "r2c":
                sl = [slice(None)] * g.ndim
                sl[d] = slice(0, p.n_out)
                g = g[tuple(sl)]
        else:  # semi
            g = _green_dct1_align(g, d, p)
    return g * norm


# ---------------------------------------------------------------------------
# forward / backward 1-D ops -- the implementations live in repro.core.engine
# (``fwd_1d`` / ``bwd_1d``, also the distributed stage API); these aliases
# keep the historical import surface for standalone callers.
# ---------------------------------------------------------------------------

_fwd_1d = fwd_1d
_bwd_1d = bwd_1d


# ---------------------------------------------------------------------------
# solver
# ---------------------------------------------------------------------------

def _fresh_jit(impl):
    """``jax.jit`` over a FRESH function object.  jitting a bound method
    directly shares jax's global trace cache across wrappers of the same
    method, so a post-reconfigure ``jax.jit(self._solve_impl)`` can silently
    replay a stale (or fault-tainted) trace whenever the call signature
    coincides; a unique closure per wrapper guarantees the retrace."""
    def call(f):
        return impl(f)
    return jax.jit(call)


class PoissonSolver:
    """u = solve(f): FFT-based solution of lap(u) = f with mixed BCs.

    ``engine``: "xla" (default) or "pallas" -- see ``repro.core.engine``.

    ``solve`` accepts ``f`` of shape ``(*grid)`` (one rhs) or ``(B, *grid)``
    (B independent right-hand sides sharing this plan, solved in ONE fused
    pipeline -- same transform count, bigger row batches).  One jit
    specialization exists per input rank/shape; the plan, schedule and
    Green's function are shared by all of them.

    Resilience (DESIGN.md #10): every ``solve`` runs under the graceful-
    degradation ladder -- on failure the solver retries transient errors
    with bounded backoff, then steps its config down one rung at a time
    (``pallas -> xla``, ``scheduled -> baseline``, ``deferred -> upfront``),
    rebuilding the pipeline each rung; the trail lands in
    ``self.stats["degradations"]`` and a terminal failure raises
    ``repro.runtime.SolveError`` with stage provenance.  ``verify``
    ("nan" | "residual", default off) arms the numerical health guards on
    every solve; a tripped guard walks the same ladder.
    """

    def __init__(self, shape, L, bcs, layout=DataLayout.CELL,
                 green_kind=gr.GreenKind.CHAT2, eps_factor=2.0,
                 engine="xla", doubling="deferred", relayout="scheduled",
                 order_policy="layout", verify=None, verify_rtol=0.5,
                 abft_rtol=0.0):
        assert relayout in RELAYOUT_MODES, relayout
        assert verify in (None, "nan", "residual", "abft",
                          "abft-stages"), verify
        self._base = dict(shape=tuple(shape), L=L, bcs=bcs, layout=layout,
                          green_kind=green_kind, eps_factor=eps_factor,
                          order_policy=order_policy)
        self.verify = verify
        self.verify_rtol = float(verify_rtol)
        # ABFT checksum tolerance; 0.0 = auto per data dtype (abft.tol_for)
        self.abft_rtol = float(abft_rtol)
        self.stats = {"solves": 0, "retries": 0, "verify_failures": 0,
                      "degradations": []}
        self._configure({"engine": as_engine(engine).name,
                         "doubling": doubling, "relayout": relayout})

    def _configure(self, cfg: dict):
        """(Re)build the whole pipeline for one runtime config -- the
        degradation ladder's rebuild hook (also the constructor's builder).
        A fresh ``jax.jit`` wrapper is installed every time, so a retry
        after a trace-time fault re-traces instead of replaying a poisoned
        cache entry."""
        b = self._base
        self._cfg = dict(cfg)
        self.plan = make_plan(b["shape"], b["L"], b["bcs"], b["layout"],
                              b["green_kind"], b["eps_factor"],
                              doubling=cfg["doubling"],
                              order_policy=b["order_policy"])
        self.engine = as_engine(cfg["engine"])
        self.schedule = build_schedule(self.plan, self.engine)
        self.relayout = cfg["relayout"]
        # ONE Green copy, held in the layout the selected pipeline
        # multiplies in: natural for baseline, the spectral LAYOUT (active
        # axis of the last forward stage minor-most) for scheduled --
        # permuted once, at plan time
        g = build_green(self.plan)
        self._green_nat = g          # natural layout: health diagnosis
        if self.relayout == "scheduled":
            g = np.ascontiguousarray(
                np.transpose(g, self.schedule.layouts.spectral))
        self._green = g
        # jit wrappers are keyed by the active fault-plan token: arming a
        # FaultPlan forces a retrace (the taint/fail_point hooks run at
        # trace time), and the clean entry is never polluted by a tainted
        # trace.  ``self._solve`` stays the clean-path jit (public-ish: the
        # batch benchmark calls it directly).
        self._solve = _fresh_jit(self._solve_impl)
        self._solve_jits = {None: self._solve}
        # ABFT wrappers live in their own caches: they trace DIFFERENT
        # programs (checksum sandwiches + report outputs), so the clean jit
        # above stays bit-exact with the checks compiled out.  ``_abft_jits``
        # holds the fully-checked pipeline (verify="abft-stages" and the
        # localization re-run); ``_lite_jits`` the cheap end-to-end
        # linearity sandwich (verify="abft"); ``_lite_weights`` the
        # plan-time Freivalds pairs (r, w = S^T r), rebuilt per config
        self._abft_jits = {}
        self._lite_jits = {}
        self._lite_weights = {}

    def _jitted(self):
        from repro.runtime import faults
        tok = faults.plan_token()
        fn = self._solve_jits.get(tok)
        if fn is None:
            fn = _fresh_jit(self._solve_impl)
            self._solve_jits[tok] = fn
        return fn

    def _abft_tol(self, dtype) -> float:
        from repro.runtime import abft
        return self.abft_rtol or abft.tol_for(dtype)

    def _abft_fresh_jit(self):
        """Jit wrapper of the CHECKED pipeline: returns ``(u, report)``
        where the report vector stacks every stage's mismatch scalar; the
        stage names are captured into ``holder`` at trace time."""
        from repro.runtime import abft
        impl = self._solve_impl
        holder: list = []

        def call(f):
            col = abft.Collector()
            u = impl(f, col=col, tol=self._abft_tol(f.dtype))
            holder[:] = col.names
            return u, col.stacked()

        return jax.jit(call), holder

    def _abft_jitted(self):
        from repro.runtime import faults
        tok = faults.plan_token()
        ent = self._abft_jits.get(tok)
        if ent is None:
            ent = self._abft_jits[tok] = self._abft_fresh_jit()
        return ent

    def _lite_reference_impl(self):
        """XLA baseline pipeline used only to build the sandwich weight
        ``w = S^T r`` via vjp.  Autodiff-safe regardless of the active
        engine (Pallas kernels carry no vjp rules) and within sandwich
        tolerance of every engine/relayout rung: same linear operator up
        to roundoff."""
        from .engine import (build_schedule, crop_doubling,
                             materialize_doubling)
        plan = self.plan
        sched = build_schedule(plan, as_engine("xla"))
        green = self._green_nat

        def impl(f):
            g = jnp.asarray(green).astype(f.dtype)
            y = materialize_doubling(f, plan.dirs)
            for d in plan.order:
                y = sched.fwd_chunk(y, d)
            y = sched.green_multiply(y, g)
            for d in reversed(plan.order):
                y = sched.bwd_chunk(y, d)
            if jnp.iscomplexobj(y):
                y = y.real
            return crop_doubling(y, plan.dirs).astype(f.dtype)

        return impl

    def _lite_pair(self, shape, dtype):
        """Plan-time Freivalds pair for one input signature: the fixed
        probe ``r`` and the weight ``w = S^T r`` (one vjp of the linear
        solve, traced under fault suppression so an armed plan cannot
        poison the reference side)."""
        from repro.runtime import abft, faults
        key = (tuple(shape), jnp.dtype(dtype).name)
        rw = self._lite_weights.get(key)
        if rw is None:
            r = jnp.asarray(abft.lite_probe(shape, dtype))
            ref = self._lite_reference_impl()
            with faults.suppressed():
                w = jax.jit(lambda rr: jax.vjp(
                    ref, jnp.zeros(shape, dtype))[1](rr)[0])(r)
                jax.block_until_ready(w)
            rw = self._lite_weights[key] = (r, w)
        return rw

    def _lite_jitted(self, shape, dtype):
        """Jit of the clean pipeline plus the end-to-end linearity
        sandwich: returns ``(u, [<r,u>, <w,f>, ||u||^2])`` -- two fused
        multiply-reduces on top of the solve, nothing per-stage."""
        from repro.runtime import faults
        tok = faults.plan_token()
        key = (tuple(shape), jnp.dtype(dtype).name, tok)
        fn = self._lite_jits.get(key)
        if fn is None:
            r, w = self._lite_pair(shape, dtype)
            impl = self._solve_impl

            def call(f):
                u = impl(f)
                rep = jnp.stack([jnp.sum(r * u), jnp.sum(w * f),
                                 jnp.sum(u * u)])
                return u, rep

            fn = self._lite_jits[key] = jax.jit(call)
        return fn

    @property
    def input_shape(self):
        return self.plan.input_shape

    def _solve_impl(self, f, col=None, tol=None):
        if self.relayout == "scheduled":
            return self._solve_scheduled(f, col, tol)
        from .engine import crop_doubling, materialize_doubling
        plan = self.plan
        sched = self.schedule
        green = jnp.asarray(self._green).astype(f.dtype)
        y = materialize_doubling(f, plan.dirs)   # no-op when deferred
        for d in plan.order:
            y = sched.fwd_chunk(y, d, col, tol)
        y = sched.green_multiply(y, green, col, tol)
        for d in reversed(plan.order):
            y = sched.bwd_chunk(y, d, col, tol)
        if jnp.iscomplexobj(y):
            y = y.real
        y = crop_doubling(y, plan.dirs)
        return y.astype(f.dtype)

    def _solve_scheduled(self, f, col=None, tol=None):
        """Layout-scheduled pipeline (DESIGN.md #9): one composed transpose
        per direction change (where the baseline moveaxis round trips paid
        two), transforms always on the minor-most axis, Green multiplied in
        the spectral layout, and -- on the Pallas engine -- the last
        forward FFT running the Green multiply as an in-register epilogue.
        Bit-exact vs the baseline path on the XLA engine (transposes only
        reorder rows; the per-row math is identical)."""
        from .engine import crop_doubling, materialize_doubling
        plan = self.plan
        sched = self.schedule
        lay = sched.layouts
        nat = tuple(range(len(plan.dirs)))
        green = jnp.asarray(self._green).astype(f.dtype)
        y = materialize_doubling(f, plan.dirs)   # no-op when deferred
        cur = nat
        for i, d in enumerate(plan.order[:-1]):
            y = _relayout(y, cur, lay.fwd[i])
            cur = lay.fwd[i]
            y = sched.fwd_last(y, d, col, tol)
        d_last = plan.order[-1]
        y = _relayout(y, cur, lay.spectral)
        y = sched.fwd_last_green(y, d_last, green, col, tol)
        cur = lay.spectral
        for i, d in enumerate(reversed(plan.order)):
            y = _relayout(y, cur, lay.bwd[i])
            cur = lay.bwd[i]
            y = sched.bwd_last(y, d, col, tol)
        y = _relayout(y, cur, nat)
        if jnp.iscomplexobj(y):
            y = y.real
        y = crop_doubling(y, plan.dirs)
        return y.astype(f.dtype)

    def solve(self, f, verify=None):
        """Solve for ``f``; ``verify`` overrides the constructor-level
        health-guard mode for this call ("nan" | "residual" | "abft" |
        "abft-stages" | None).  ``"abft"`` (DESIGN.md #13) is the
        two-phase guard: every solve runs the cheap end-to-end linearity
        sandwich, and only a tripped sandwich re-dispatches through the
        fully-checked pipeline to localize the stage, selectively repair
        it, and raise ``IntegrityError`` into the degradation ladder if
        the corruption persists.  ``"abft-stages"`` runs the checked
        pipeline unconditionally (per-stage sandwiches with inline
        selective recompute -- the chaos net's mode)."""
        from repro.runtime import abft, faults, health, resilience
        f = jnp.asarray(f)
        grid = self.input_shape
        assert (f.ndim in (len(grid), len(grid) + 1)
                and f.shape[f.ndim - len(grid):] == grid), (f.shape, grid)
        verify = self.verify if verify is None else verify
        self.stats["solves"] += 1

        def checked():
            fn, names = self._abft_jitted()
            u, rep = fn(f)
            abft.verify_report(
                list(names), np.asarray(rep),
                tol=self._abft_tol(f.dtype), stats=self.stats,
                describe="solve")
            return u

        def attempt():
            faults.fail_point("solve.dispatch")
            if verify == "abft-stages":
                return checked()
            if verify == "abft":
                u, rep = self._lite_jitted(f.shape, f.dtype)(f)
                m = abft.lite_mismatch(np.asarray(rep))
                tol = self._abft_tol(f.dtype) * abft.LITE_HEADROOM
                if m <= tol:
                    return u
                # sandwich tripped: localize via the checked pipeline
                # (selective inline repair; persistent corruption raises
                # IntegrityError out of verify_report into the ladder)
                self.stats["verify_failures"] += 1
                self.stats.setdefault("integrity", []).append({
                    "stage": "solve.linearity", "kind": "linearity",
                    "mismatch": float(m), "tol": float(tol),
                    "action": "localize", "describe": "solve"})
                return checked()
            u = self._jitted()(f)
            if verify:
                health.check_solution(
                    u, f, self.plan, mode=verify, rtol=self.verify_rtol,
                    stats=self.stats,
                    locate=lambda: health.locate_nonfinite_stage(
                        self.plan, self.schedule, f, self._green_nat))
            return u

        return resilience.run_with_ladder(
            attempt, config=self._cfg, reconfigure=self._configure,
            stats=self.stats, describe="solve")


# ---------------------------------------------------------------------------
# global plan/solver cache
# ---------------------------------------------------------------------------
#
# A CFD-style driver (e.g. a vortex-method timestepper, or the launch CLI
# re-entered every step) constructs the SAME solver over and over: identical
# shape/L/bcs/layout/green/engine/comm.  Planning is not free -- Green's
# function assembly is O(N^3) numpy work, autotuning compiles candidate
# pipelines, and every fresh ``jax.jit`` wrapper restarts XLA compilation.
# ``get_solver`` memoizes fully-constructed solvers in a module-level LRU
# keyed by the complete plan identity, so repeated construction costs a
# dict lookup and the jit/plan/Green work happens once per process.

_SOLVER_CACHE: OrderedDict = OrderedDict()
_SOLVER_CACHE_LOCK = threading.Lock()
# key -> in-flight construction (single-flight): N concurrent misses for
# the same key build the solver ONCE; the other N-1 callers park on the
# builder's event and are handed the same instance ("coalesced" in stats).
# Without this the miss path built outside the lock, so a thundering herd
# paid plan+autotune+jit N times and the last insert silently overwrote
# the N-1 siblings (skewing hit/miss/eviction accounting on top).
_SOLVER_BUILDS: dict = {}
_SOLVER_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0,
                       "coalesced": 0, "build_failures": 0}
_SOLVER_CACHE_CAPACITY = 16


class _SolverBuild:
    """One in-flight get_solver construction: the builder thread fills
    ``result``/``exc`` and sets ``done``; coalesced waiters block on it."""

    __slots__ = ("done", "result", "exc")

    def __init__(self):
        self.done = threading.Event()
        self.result = None
        self.exc = None


def _freeze(v):
    """Canonical hashable form of one get_solver argument."""
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    try:
        hash(v)
        return v
    except TypeError:
        return repr(v)


def get_solver(shape, L, bcs, layout=DataLayout.CELL,
               green_kind=gr.GreenKind.CHAT2, eps_factor=2.0,
               engine="xla", doubling="deferred", relayout="scheduled",
               order_policy="layout", *, mesh=None, **kw):
    """Construct-or-fetch a solver from the global plan cache.

    Returns a ``PoissonSolver``, or a ``DistributedPoissonSolver`` when
    ``mesh`` is given (extra distributed keywords -- ``comm``, ``axes``,
    ``batch_axis``, ``dtype``, autotune knobs, ... -- pass through and are
    part of the cache key, as is the mesh itself: same devices + same axis
    names hit the same entry).  Entries are evicted least-recently-used
    beyond ``set_solver_cache_capacity`` (default 16 solvers).

    Construction is SINGLE-FLIGHT per key: when N threads miss the same
    key concurrently (the serve thundering herd), exactly one of them
    builds -- the rest park on the builder and receive the same instance
    (counted as ``coalesced`` in ``solver_cache_info``).  A failed build
    re-raises in every parked caller and leaves no cache entry behind, so
    the next request retries cleanly.
    """
    from repro.runtime import faults
    key = ("dist" if mesh is not None else "single",
           _freeze(shape), _freeze(L), _freeze(bcs), _freeze(layout),
           _freeze(green_kind), float(eps_factor),
           as_engine(engine), str(doubling), str(relayout),
           str(order_policy), _freeze(mesh), _freeze(kw),
           # solvers traced under an armed fault plan must never be served
           # to fault-free callers (their jit cache may carry the fault)
           ("faults", faults.plan_token()))
    builder = False
    with _SOLVER_CACHE_LOCK:
        s = _SOLVER_CACHE.get(key)
        if s is not None:
            _SOLVER_CACHE.move_to_end(key)
            _SOLVER_CACHE_STATS["hits"] += 1
            return s
        build = _SOLVER_BUILDS.get(key)
        if build is None:
            build = _SOLVER_BUILDS[key] = _SolverBuild()
            _SOLVER_CACHE_STATS["misses"] += 1
            builder = True
        else:
            # another thread is already constructing this key: park on its
            # build instead of duplicating the plan/autotune/jit work
            _SOLVER_CACHE_STATS["coalesced"] += 1
    if not builder:
        build.done.wait()
        if build.exc is not None:
            raise build.exc
        return build.result
    try:
        if mesh is not None:
            from repro.distributed.pencil import DistributedPoissonSolver
            s = DistributedPoissonSolver(shape, L, bcs, layout, green_kind,
                                         mesh=mesh, eps_factor=eps_factor,
                                         engine=engine, doubling=doubling,
                                         relayout=relayout,
                                         order_policy=order_policy, **kw)
        else:
            assert set(kw) <= {"verify", "verify_rtol", "abft_rtol"}, \
                f"unexpected single-process solver kwargs: {kw}"
            s = PoissonSolver(shape, L, bcs, layout, green_kind, eps_factor,
                              engine=engine, doubling=doubling,
                              relayout=relayout, order_policy=order_policy,
                              **kw)
    except BaseException as e:
        with _SOLVER_CACHE_LOCK:
            _SOLVER_BUILDS.pop(key, None)
            _SOLVER_CACHE_STATS["build_failures"] += 1
        build.exc = e
        build.done.set()
        raise
    with _SOLVER_CACHE_LOCK:
        _SOLVER_CACHE[key] = s
        _SOLVER_CACHE.move_to_end(key)
        while len(_SOLVER_CACHE) > _SOLVER_CACHE_CAPACITY:
            _SOLVER_CACHE.popitem(last=False)
            _SOLVER_CACHE_STATS["evictions"] += 1
        _SOLVER_BUILDS.pop(key, None)
    build.result = s
    build.done.set()
    return s


def clear_solver_cache():
    """Drop every cached solver and reset cache stats.  Also resets the
    process-wide warn-once state (``comm`` + ``resilience`` diagnostics):
    a fresh cache means fresh plans, and their one-shot warnings must be
    able to fire again -- long-lived servers and test fixtures both call
    this as THE runtime reset hook."""
    with _SOLVER_CACHE_LOCK:
        _SOLVER_CACHE.clear()
        for k in _SOLVER_CACHE_STATS:
            _SOLVER_CACHE_STATS[k] = 0
    from . import comm as _comm
    from repro.runtime import resilience as _resilience
    _comm.reset_warn_once()
    _resilience.reset_warn_once()


def evict_solver_instance(solver) -> int:
    """Drop the cache entries holding exactly ``solver`` (identity, not
    equality).  The serve warm pool calls this when its memory budget
    evicts a plan, so the global LRU cannot keep the Green's function and
    jit executables alive behind the pool's back.  Returns the eviction
    count."""
    with _SOLVER_CACHE_LOCK:
        stale = [k for k, v in _SOLVER_CACHE.items() if v is solver]
        for k in stale:
            del _SOLVER_CACHE[k]
            _SOLVER_CACHE_STATS["evictions"] += 1
    return len(stale)


def evict_solver_entries(mesh) -> int:
    """Drop every cached solver planned against ``mesh`` (elastic
    recovery: after a device loss the old mesh's solvers hold dead
    devices and must never be served again).  Returns the eviction
    count."""
    frozen = _freeze(mesh)
    with _SOLVER_CACHE_LOCK:
        stale = [k for k in _SOLVER_CACHE if frozen in k]
        for k in stale:
            del _SOLVER_CACHE[k]
            _SOLVER_CACHE_STATS["evictions"] += 1
    return len(stale)


def solver_cache_info() -> dict:
    with _SOLVER_CACHE_LOCK:
        return dict(_SOLVER_CACHE_STATS, size=len(_SOLVER_CACHE),
                    capacity=_SOLVER_CACHE_CAPACITY)


def set_solver_cache_capacity(n: int):
    global _SOLVER_CACHE_CAPACITY
    assert n >= 1, n
    with _SOLVER_CACHE_LOCK:
        _SOLVER_CACHE_CAPACITY = int(n)
        while len(_SOLVER_CACHE) > _SOLVER_CACHE_CAPACITY:
            _SOLVER_CACHE.popitem(last=False)
            _SOLVER_CACHE_STATS["evictions"] += 1
