"""Spectral differentiation with the DCT<->DST swap rules (paper section V).

Applying d/dx in a symmetric direction flips the boundary condition
(even <-> odd) and therefore the transform family used on the way back:

  * forward DST, multiply by +omega, backward as DCT coefficients
  * forward DCT, multiply by -omega, backward as DST coefficients

(the +/- comes from the DST output representing (0 - i f~) and the DCT
output (f~ + 0i) as complex numbers, see the paper).  Integer-mode types
(DCT1/DST1, DCT2/DST2) shift storage by one (mode k lives at k - koffset);
half-mode types (DCT3/4, DST3/4) map index-to-index.  Periodic/unbounded
(complex DFT) directions multiply by i*omega.

Finite-difference symbols (paper eqs. 12-14) replace omega by

  order 2:  sin(w h) / h
  order 4:  (4/3 sin(w h) - 1/6 sin(2 w h)) / h
  order 6:  (3/2 sin(w h) - 3/10 sin(2 w h) + 1/30 sin(3 w h)) / h
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .bc import BCType, TransformKind
from .engine import on_last_axis
from .solver import Plan1D

__all__ = ["fd_symbol", "swap_bc", "apply_derivative"]

_DCT_KINDS = (TransformKind.DCT1, TransformKind.DCT2,
              TransformKind.DCT3, TransformKind.DCT4)
_DST_KINDS = (TransformKind.DST1, TransformKind.DST2,
              TransformKind.DST3, TransformKind.DST4)

SWAP = {
    TransformKind.DCT1: TransformKind.DST1,
    TransformKind.DST1: TransformKind.DCT1,
    TransformKind.DCT2: TransformKind.DST2,
    TransformKind.DST2: TransformKind.DCT2,
    TransformKind.DCT3: TransformKind.DST3,
    TransformKind.DST3: TransformKind.DCT3,
    TransformKind.DCT4: TransformKind.DST4,
    TransformKind.DST4: TransformKind.DCT4,
}


def swap_bc(bc: BCType) -> BCType:
    if bc == BCType.EVEN:
        return BCType.ODD
    if bc == BCType.ODD:
        return BCType.EVEN
    return bc  # periodic / unbounded unchanged


def fd_symbol(omega: np.ndarray, h: float, order: int) -> np.ndarray:
    """Modified wavenumber for the chosen differentiation order (0=spectral)."""
    if order == 0:
        return omega
    s1 = np.sin(omega * h)
    if order == 2:
        return s1 / h
    s2 = np.sin(2.0 * omega * h)
    if order == 4:
        return (4.0 / 3.0 * s1 - 1.0 / 6.0 * s2) / h
    s3 = np.sin(3.0 * omega * h)
    if order == 6:
        return (1.5 * s1 - 0.3 * s2 + s3 / 30.0) / h
    raise ValueError(f"unsupported FD order {order}")


def apply_derivative(yhat, p_from: Plan1D, p_to: Plan1D, fd_order: int = 0):
    """d/dx_d in spectral space: map ``yhat`` (transformed with ``p_from``)
    into the storage/basis of ``p_to`` along dimension ``p_from.dim``.

    For complex (DFT) directions ``p_to`` must equal ``p_from``; for r2r
    directions ``p_to.kind`` must be the swapped family.
    """
    d = p_from.dim
    if p_from.category in ("per", "unb"):
        assert p_to.n_out == p_from.n_out
        w = fd_symbol(np.asarray(p_from.modes), p_from.h, fd_order)
        shape = [1] * yhat.ndim
        shape[d] = len(w)
        return yhat * (1j * w.reshape(shape)).astype(
            jnp.complex128 if yhat.dtype == jnp.complex128 else jnp.complex64)

    assert p_to.kind == SWAP[p_from.kind], (p_from.kind, p_to.kind)
    sign = 1.0 if p_from.kind in _DST_KINDS else -1.0
    # mode k sits at storage index k - koffset
    w_to = fd_symbol(np.asarray(p_to.modes), p_to.h, fd_order)

    def swap_last(y):
        # gather the input coefficient for each output mode
        out = jnp.zeros(y.shape[:-1] + (p_to.n_out,), dtype=y.dtype)
        # overlapping mode range
        mode_lo = max(p_from.koffset, p_to.koffset)
        mode_hi = min(p_from.koffset + p_from.n_out,
                      p_to.koffset + p_to.n_out)
        src = slice(mode_lo - p_from.koffset, mode_hi - p_from.koffset)
        dst = slice(mode_lo - p_to.koffset, mode_hi - p_to.koffset)
        fac = (sign * w_to[dst]).astype(y.dtype)
        return out.at[..., dst].set(y[..., src] * fac)

    return on_last_axis(yhat, d, swap_last)
