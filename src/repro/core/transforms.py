"""1-D transforms used by the solver, all on the LAST axis.

Every real-to-real transform (DCT/DST types I-IV) runs a HALF-SPECTRUM real
FFT (``jnp.fft.rfft`` / ``irfft``) on the real (anti)symmetric extension --
half the FLOPs and bytes of the full-complex algorithm (kept in
``transforms_ref`` as the old-path baseline).  No complex intermediates exist
before the twiddle: forward transforms post-twiddle the rfft half spectrum
(``y = a * re + b * im``, the ``twiddle_pack`` kernel shape), inverse-family
transforms pre-twiddle the real input into the half spectrum consumed by
``irfft``.  All conventions match ``scipy.fft`` unnormalized ("backward") --
scipy is the oracle in the tests.

Twiddle tables are precomputed per ``(kind, m)`` (``twiddle_tables``, cached)
so a plan's ``TransformSchedule`` can hand them to the Pallas post-twiddle
kernel; constant factors (the 2M of the type-III inverses) are folded into
the tables, so no transform performs a standalone scaling multiply.

The pencil engine always shuffles the active direction to the last axis
(flups' ``shuffle()``), so all transforms here are axis=-1.

Engine selection: every public transform takes ``engine=None`` (pure XLA) or
a ``repro.core.engine.TransformEngine``; ``engine="pallas"`` routes the
post-twiddle through the ``twiddle_pack`` Pallas kernel and power-of-two
rfft/irfft through the ``fft_stockham`` kernel (see ``repro.kernels.ops``).
On power-of-two lengths the forward post-twiddle kinds (dct1/dct2/dst2)
run the FUSED ``rfft_twiddle`` kernel instead -- the twiddle executes in
the FFT's final-stage registers, one HBM round trip instead of three
(DESIGN.md #9).
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np
import jax.numpy as jnp

from .bc import TransformKind

__all__ = [
    "dct1", "dct2", "dct3", "dct4",
    "dst1", "dst2", "dst3", "dst4",
    "r2r_forward", "r2r_backward", "r2r_normfact", "twiddle_tables",
]


def _rdtype(x):
    return x.dtype


def _use_pallas(engine) -> bool:
    return engine is not None and getattr(engine, "use_pallas", False)


def _pow2(n: int) -> bool:
    return n >= 2 and (n & (n - 1)) == 0


def _scan_dtype(dtype):
    """Widest float for the O(M) prefix sums of dst1 / odd-M dct4: their
    roundoff accumulates linearly along the axis, so run them in f64 when
    x64 is enabled (and stay put otherwise -- requesting f64 under
    disabled x64 would only emit a truncation warning)."""
    import jax
    return jnp.float64 if jax.config.jax_enable_x64 else dtype


# ---------------------------------------------------------------------------
# engine-aware FFT backends (jnp by default, Stockham kernel for pallas)
# ---------------------------------------------------------------------------

def _rfft(z, engine):
    if _use_pallas(engine) and _pow2(z.shape[-1]):
        from repro.kernels import ops
        return ops.rfft_pallas(z, interpret=engine.interpret,
                               max_radix=engine.max_radix)
    return jnp.fft.rfft(z, axis=-1)


def _irfft(c, n, engine):
    if _use_pallas(engine) and _pow2(n):
        from repro.kernels import ops
        return ops.irfft_pallas(c, n, interpret=engine.interpret,
                                max_radix=engine.max_radix)
    return jnp.fft.irfft(c, n=n, axis=-1)


def _cfft(z, engine, inverse=False):
    """Engine-aware complex FFT over the last axis (the solver's c2c dirs)."""
    if not jnp.iscomplexobj(z):
        z = z.astype(jnp.complex128 if z.dtype == jnp.float64
                     else jnp.complex64)
    if _use_pallas(engine) and _pow2(z.shape[-1]):
        from repro.kernels import ops
        return ops.fft1d(z, inverse=inverse, interpret=engine.interpret,
                         max_radix=engine.max_radix)
    return (jnp.fft.ifft if inverse else jnp.fft.fft)(z, axis=-1)


# ---------------------------------------------------------------------------
# pruned DFT variants (Hockney doubling: length-n_fft spectra of signals
# whose tail is identically zero / inverses of which only a head is kept)
# ---------------------------------------------------------------------------

def _zpad(x, n_fft):
    pad = [(0, 0)] * (x.ndim - 1) + [(0, n_fft - x.shape[-1])]
    return jnp.pad(x, pad)


def _rfft_padded(x, n_fft, engine):
    """Length-``n_fft`` half spectrum of ``[x, 0, ..., 0]`` from only the
    ``x.shape[-1]`` nonzero inputs.  The Pallas engine skips the zero tail
    inside the Stockham kernel (first stage reads half the VMEM and does no
    dead adds); the XLA engine pads -- jnp.fft has no pruned entry point,
    and the explicit pad keeps the result BIT-IDENTICAL to a dense plan's
    (the pruned-vs-dense equality tests rely on this)."""
    n_in = x.shape[-1]
    if n_in == n_fft:
        return _rfft(x, engine)
    if _use_pallas(engine) and _pow2(n_fft) and n_fft == 2 * n_in:
        from repro.kernels import ops
        return ops.rfft_pallas(x, pad_to=n_fft, interpret=engine.interpret,
                               max_radix=engine.max_radix)
    return _rfft(_zpad(x, n_fft), engine)


def _cfft_padded(z, n_fft, engine):
    """Length-``n_fft`` complex spectrum of the zero-tail-extended ``z``."""
    n_in = z.shape[-1]
    if n_in == n_fft:
        return _cfft(z, engine)
    if (_use_pallas(engine) and _pow2(n_fft) and n_fft == 2 * n_in
            and jnp.iscomplexobj(z)):
        from repro.kernels import ops
        return ops.fft1d(z, pad_to=n_fft, interpret=engine.interpret,
                         max_radix=engine.max_radix)
    return _cfft(_zpad(z, n_fft), engine)


def _irfft_crop(y, n_fft, keep, engine):
    """First ``keep`` samples of the length-``n_fft`` irfft.  The Pallas
    engine reconstructs only the retained half via the parity split (two
    half-length inverse FFTs); XLA reconstructs fully and crops."""
    if keep >= n_fft:
        return _irfft(y, n_fft, engine)
    if (_use_pallas(engine) and _pow2(n_fft) and n_fft >= 4
            and keep <= n_fft // 2):
        from repro.kernels import ops
        return ops.irfft_pruned(y, n_fft, keep, interpret=engine.interpret,
                                max_radix=engine.max_radix)
    return _irfft(y, n_fft, engine)[..., :keep]


def _icfft_crop(z, keep, engine):
    """First ``keep`` samples of the inverse complex FFT of ``z``."""
    n_fft = z.shape[-1]
    if keep >= n_fft:
        return _cfft(z, engine, inverse=True)
    if (_use_pallas(engine) and _pow2(n_fft) and n_fft >= 4
            and keep <= n_fft // 2):
        from repro.kernels import ops
        return ops.ifft_pruned(z, keep, interpret=engine.interpret,
                               max_radix=engine.max_radix)
    return _cfft(z, engine, inverse=True)[..., :keep]


def _post(re, im, a, b, engine, out_dtype):
    """y = a * re + b * im along the last axis (the r2r post-twiddle)."""
    if _use_pallas(engine):
        from repro.kernels import ops
        return ops.post_twiddle(re, im, a, b,
                                interpret=engine.interpret).astype(out_dtype)
    av = jnp.asarray(a, dtype=out_dtype)
    bv = jnp.asarray(b, dtype=out_dtype)
    return (av * re + bv * im).astype(out_dtype)


# ---------------------------------------------------------------------------
# twiddle tables (plan-time constants, float64; cast at use)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def twiddle_tables(kind: TransformKind, m: int):
    """Precomputed twiddle constants for a size-``m`` transform of ``kind``.

    Keys (all values ``np.float64``):
      post_a/post_b  forward post-twiddle  ``y = a*re + b*im``
      pre_re/pre_im  inverse-family pre-twiddle (2M factor folded in)
      split_c/split_s  type-IV cos/sin input split
    """
    if kind == TransformKind.DCT1:
        return {}
    if kind == TransformKind.DST1:
        # NR-style auxiliary sequence for the length-(m+1) rfft formulation
        j = np.arange(m + 1)
        return {"aux_sin": np.sin(np.pi * j / (m + 1.0))}
    if kind == TransformKind.DCT2:
        k = np.arange(m)
        th = np.pi * k / (2.0 * m)
        return {"post_a": np.cos(th), "post_b": np.sin(th)}
    if kind == TransformKind.DST2:
        k = np.arange(1, m + 1)
        th = np.pi * k / (2.0 * m)
        return {"post_a": np.sin(th), "post_b": -np.cos(th)}
    if kind == TransformKind.DCT3:
        k = np.arange(m)
        th = np.pi * k / (2.0 * m)
        return {"pre_re": 2.0 * m * np.cos(th),
                "pre_im": 2.0 * m * np.sin(th)}
    if kind == TransformKind.DST3:
        k = np.arange(1, m + 1)
        th = np.pi * k / (2.0 * m)
        return {"pre_re": 2.0 * m * np.sin(th),
                "pre_im": -2.0 * m * np.cos(th)}
    if kind in (TransformKind.DCT4, TransformKind.DST4):
        n = np.arange(m)
        b = np.pi * (2 * n + 1) / (4.0 * m)
        t = {"split_c": np.cos(b), "split_s": np.sin(b),
             "alt_sign": (-1.0) ** n}
        if m % 2 == 0:
            # half-length complex-FFT formulation (see dct4): pre-twiddle
            # e^{-i pi (4p+1)/(4M)} on z_p = x_{2p} + i x_{M-1-2p}, post
            # e^{-i pi q/M} on the length-M/2 spectrum
            p = np.arange(m // 2)
            pre = np.pi * (4 * p + 1) / (4.0 * m)
            post = np.pi * p / m
            t.update(q4_pre_re=np.cos(pre), q4_pre_im=-np.sin(pre),
                     q4_post_re=np.cos(post), q4_post_im=-np.sin(post))
        return t
    raise ValueError(kind)


def _tables(kind, m, tables):
    return twiddle_tables(kind, m) if tables is None else tables


# ---------------------------------------------------------------------------
# DCT types
# ---------------------------------------------------------------------------

def _rfft_twiddle_fused(z, a, b, start, count, engine, out_dtype):
    """Fused rfft + post-twiddle (``a*re + b*im`` over ``count`` bins from
    ``start``) when the Pallas engine can run it as ONE kernel; None when
    the caller must take the unfused rfft + ``_post`` path."""
    if not (_use_pallas(engine) and _pow2(z.shape[-1])):
        return None
    from repro.kernels import ops
    return ops.rfft_twiddle(z, a[:count], b[:count], start=start,
                            interpret=engine.interpret,
                            max_radix=engine.max_radix).astype(out_dtype)


def dct1(x, engine=None, tables=None):
    """DCT-I: y_k = x_0 + (-1)^k x_{M-1} + 2 sum_{n=1}^{M-2} x_n cos(pi k n/(M-1)).

    Even extension of length 2(M-1); the rfft of a real even signal is real,
    and its M half-spectrum bins are exactly the DCT-I coefficients.
    """
    m = x.shape[-1]
    z = jnp.concatenate([x, x[..., -2:0:-1]], axis=-1)  # even ext, len 2(M-1)
    fused = _rfft_twiddle_fused(z, np.ones(m), np.zeros(m), 0, m, engine,
                                _rdtype(x))
    if fused is not None:
        return fused
    return _rfft(z, engine).real.astype(_rdtype(x))


def dct2(x, engine=None, tables=None):
    """DCT-II: y_k = 2 sum_n x_n cos(pi k (2n+1) / (2M))."""
    m = x.shape[-1]
    t = _tables(TransformKind.DCT2, m, tables)
    z = jnp.concatenate([x, x[..., ::-1]], axis=-1)     # even ext, len 2M
    fused = _rfft_twiddle_fused(z, t["post_a"], t["post_b"], 0, m, engine,
                                _rdtype(x))
    if fused is not None:
        return fused
    f = _rfft(z, engine)[..., :m]
    return _post(f.real, f.imag, t["post_a"], t["post_b"], engine, _rdtype(x))


def dct3(x, engine=None, tables=None):
    """DCT-III: y_k = x_0 + 2 sum_{n=1}^{M-1} x_n cos(pi n (2k+1) / (2M)).

    Pre-twiddle the real input into the hermitian half spectrum whose
    length-2M irfft carries the DCT-III in its first M samples (the 2M
    normalization of irfft is folded into the twiddle table).
    """
    m = x.shape[-1]
    t = _tables(TransformKind.DCT3, m, tables)
    dt = jnp.complex128 if x.dtype == jnp.float64 else jnp.complex64
    c = (x * jnp.asarray(t["pre_re"], x.dtype) +
         1j * (x * jnp.asarray(t["pre_im"], x.dtype))).astype(dt)
    c = jnp.concatenate(
        [c, jnp.zeros(x.shape[:-1] + (1,), dtype=dt)], axis=-1)
    return _irfft(c, 2 * m, engine)[..., :m].astype(_rdtype(x))


def dct4(x, engine=None, tables=None):
    """DCT-IV: y_k = 2 sum_n x_n cos(pi (2k+1)(2n+1) / (4M)).

    Standard half-length formulation (even M, the MDCT/FFTW-style
    algorithm): fold the input into the length-M/2 complex sequence
    z_p = (x_{2p} + i x_{M-1-2p}) e^{-i pi (4p+1)/(4M)}; with
    t_q = FFT_{M/2}(z)_q e^{-i pi q/M} the outputs are
    y_{2q} = 2 Re t_q and y_{M-1-2q} = -2 Im t_q -- ONE complex FFT of
    length M/2 where the old path ran two length-2M real extensions (a
    DCT2 + a DST2), the BENCH_kernels laggard.

    Odd M falls back to the product-to-sum identity: with
    c_n = x_n cos(pi(2n+1)/(4M)),  y_k + y_{k-1} = 2 DCT2(c)_k (and
    y_0 = DCT2(c)_0), i.e. one DCT-II plus an O(M) alternating prefix sum
    y_k = (-1)^k [Y_0 + 2 sum_{j=1..k} (-1)^j Y_j].
    """
    m = x.shape[-1]
    t = _tables(TransformKind.DCT4, m, tables)
    dtype = _rdtype(x)
    if m % 2 == 0:
        dt = jnp.complex128 if dtype == jnp.float64 else jnp.complex64
        a = x[..., 0::2]                      # x_{2p}
        b = x[..., ::-1][..., 0::2]           # x_{M-1-2p}
        pre = (jnp.asarray(t["q4_pre_re"], dtype)
               + 1j * jnp.asarray(t["q4_pre_im"], dtype)).astype(dt)
        post = (jnp.asarray(t["q4_post_re"], dtype)
                + 1j * jnp.asarray(t["q4_post_im"], dtype)).astype(dt)
        z = (a.astype(dt) + 1j * b.astype(dt)) * pre
        tq = _cfft(z, engine) * post
        even = (2.0 * tq.real).astype(dtype)          # y_{2q}
        odd = (-2.0 * tq.imag[..., ::-1]).astype(dtype)   # y_{1+2r}
        return jnp.stack([even, odd], axis=-1).reshape(x.shape)
    c = (x * jnp.asarray(t["split_c"], dtype=dtype)).astype(dtype)
    y2 = dct2(c, engine).astype(_scan_dtype(dtype))
    sgn = jnp.asarray(t["alt_sign"], y2.dtype)
    cs = jnp.cumsum(sgn * y2, axis=-1)
    return (sgn * (2.0 * cs - y2[..., :1])).astype(dtype)


# ---------------------------------------------------------------------------
# DST types
# ---------------------------------------------------------------------------

def dst1(x, engine=None, tables=None):
    """DST-I: y_k = 2 sum_n x_n sin(pi (k+1)(n+1) / (M+1)).

    Standard length-N formulation (N = M+1, the Numerical-Recipes
    auxiliary sequence): with u = [0, x] and its reversal ur = [0, rev(x)],
    the rfft Y of  v_j = sin(pi j/N)(u_j + ur_j) + (u_j - ur_j)/2  carries
    the even coefficients directly (y_{2k} = -2 Im Y_k) and the odd ones as
    a prefix sum (y_{2k+1} = Re Y_0 + 2 sum_{j=1..k} Re Y_j) -- ONE rfft of
    length M+1 instead of the old odd extension's rfft of length 2(M+1).
    """
    m = x.shape[-1]
    t = _tables(TransformKind.DST1, m, tables)
    dtype = _rdtype(x)
    s = jnp.asarray(t["aux_sin"], dtype=dtype)                 # sin(pi j/N)
    zeros = jnp.zeros(x.shape[:-1] + (1,), dtype=x.dtype)
    u = jnp.concatenate([zeros, x], axis=-1)                   # u_j
    ur = jnp.concatenate([zeros, x[..., ::-1]], axis=-1)       # u_{N-j}
    v = s * (u + ur) + 0.5 * (u - ur)
    f = _rfft(v, engine)                                       # bins 0..N//2
    n_odd = (m + 1) // 2                                       # y_1, y_3, ...
    n_even = m // 2                                            # y_2, y_4, ...
    re = f.real[..., :n_odd].astype(_scan_dtype(dtype))
    odd = (2.0 * jnp.cumsum(re, axis=-1) - re[..., :1]).astype(dtype)
    even = (-2.0 * f.imag[..., 1:n_even + 1]).astype(dtype)
    if n_even < n_odd:                                         # odd M
        even = jnp.concatenate(
            [even, jnp.zeros(x.shape[:-1] + (1,), dtype=dtype)], axis=-1)
    out = jnp.stack([odd, even], axis=-1).reshape(x.shape[:-1] + (2 * n_odd,))
    return out[..., :m]


def dst2(x, engine=None, tables=None):
    """DST-II: y_k = 2 sum_n x_n sin(pi (k+1)(2n+1) / (2M))."""
    m = x.shape[-1]
    t = _tables(TransformKind.DST2, m, tables)
    z = jnp.concatenate([x, -x[..., ::-1]], axis=-1)    # odd ext, len 2M
    fused = _rfft_twiddle_fused(z, t["post_a"], t["post_b"], 1, m, engine,
                                _rdtype(x))
    if fused is not None:
        return fused
    f = _rfft(z, engine)[..., 1:m + 1]
    return _post(f.real, f.imag, t["post_a"], t["post_b"], engine, _rdtype(x))


def dst3(x, engine=None, tables=None):
    """DST-III: y_k = (-1)^k x_{M-1} + 2 sum_{n=0}^{M-2} x_n sin(pi (n+1)(2k+1)/(2M)).

    Mirror of dct3: pre-twiddle into bins 1..M of the half spectrum (bin 0
    stays zero), irfft, keep the first M samples.
    """
    m = x.shape[-1]
    t = _tables(TransformKind.DST3, m, tables)
    dt = jnp.complex128 if x.dtype == jnp.float64 else jnp.complex64
    c = (x * jnp.asarray(t["pre_re"], x.dtype) +
         1j * (x * jnp.asarray(t["pre_im"], x.dtype))).astype(dt)
    c = jnp.concatenate(
        [jnp.zeros(x.shape[:-1] + (1,), dtype=dt), c], axis=-1)
    return _irfft(c, 2 * m, engine)[..., :m].astype(_rdtype(x))


def dst4(x, engine=None, tables=None):
    """DST-IV: y_k = 2 sum_n x_n sin(pi (2k+1)(2n+1) / (4M)).

    Reversal identity: DST4(x)_k = (-1)^k DCT4(rev(x))_k, so the type-IV
    sine transform rides the half-length complex-FFT dct4 for free (the
    twiddle-table layout is shared by the two kinds).
    """
    m = x.shape[-1]
    t = _tables(TransformKind.DST4, m, tables)
    sgn = jnp.asarray(t["alt_sign"], dtype=_rdtype(x))
    return sgn * dct4(x[..., ::-1], engine=engine, tables=t)


# ---------------------------------------------------------------------------
# dispatch + normalization
# ---------------------------------------------------------------------------

_FWD = {
    TransformKind.DCT1: dct1, TransformKind.DCT2: dct2,
    TransformKind.DCT3: dct3, TransformKind.DCT4: dct4,
    TransformKind.DST1: dst1, TransformKind.DST2: dst2,
    TransformKind.DST3: dst3, TransformKind.DST4: dst4,
}

_INV = {
    TransformKind.DCT1: dct1, TransformKind.DCT2: dct3,
    TransformKind.DCT3: dct2, TransformKind.DCT4: dct4,
    TransformKind.DST1: dst1, TransformKind.DST2: dst3,
    TransformKind.DST3: dst2, TransformKind.DST4: dst4,
}


def r2r_normfact(kind: TransformKind, m: int) -> float:
    """1 / (forward o backward) amplification for size-m transforms."""
    if kind in (TransformKind.DCT1,):
        return 1.0 / (2.0 * (m - 1))
    if kind in (TransformKind.DST1,):
        return 1.0 / (2.0 * (m + 1))
    return 1.0 / (2.0 * m)


def r2r_forward(x, kind: TransformKind, engine=None, tables=None):
    return _FWD[kind](x, engine=engine, tables=tables)


def r2r_backward(y, kind: TransformKind, engine=None, tables=None):
    """Unnormalized inverse; the solver folds ``r2r_normfact`` into the
    Green's function (standalone callers multiply by it themselves)."""
    return _INV[kind](y, engine=engine, tables=tables)
