"""1-D transforms used by the solver, all on the LAST axis.

Every real-to-real transform (DCT/DST types I-IV) runs a HALF-SPECTRUM real
FFT (``jnp.fft.rfft`` / ``irfft``) on the real (anti)symmetric extension --
half the FLOPs and bytes of the full-complex algorithm (kept in
``transforms_ref`` as the old-path baseline).  No complex intermediates exist
before the twiddle: forward transforms post-twiddle the rfft half spectrum
(``y = a * re + b * im``, the ``twiddle_pack`` kernel shape), inverse-family
transforms pre-twiddle the real input into the half spectrum consumed by
``irfft``.  All conventions match ``scipy.fft`` unnormalized ("backward") --
scipy is the oracle in the tests.

Twiddle tables are precomputed per ``(kind, m)`` (``twiddle_tables``, cached)
so a plan's ``TransformSchedule`` can hand them to the Pallas post-twiddle
kernel; constant factors (the 2M of the type-III inverses) are folded into
the tables, so no transform performs a standalone scaling multiply.

The pencil engine always shuffles the active direction to the last axis
(flups' ``shuffle()``), so all transforms here are axis=-1.

Engine selection: every public transform takes ``engine=None`` (pure XLA) or
a ``repro.core.engine.TransformEngine``; ``engine="pallas"`` routes the
post-twiddle through the ``twiddle_pack`` Pallas kernel and power-of-two
rfft/irfft through the ``fft_stockham`` kernel (see ``repro.kernels.ops``).
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np
import jax.numpy as jnp

from .bc import TransformKind

__all__ = [
    "dct1", "dct2", "dct3", "dct4",
    "dst1", "dst2", "dst3", "dst4",
    "r2r_forward", "r2r_backward", "r2r_normfact", "twiddle_tables",
]


def _rdtype(x):
    return x.dtype


def _use_pallas(engine) -> bool:
    return engine is not None and getattr(engine, "use_pallas", False)


def _pow2(n: int) -> bool:
    return n >= 2 and (n & (n - 1)) == 0


# ---------------------------------------------------------------------------
# engine-aware FFT backends (jnp by default, Stockham kernel for pallas)
# ---------------------------------------------------------------------------

def _rfft(z, engine):
    if _use_pallas(engine) and _pow2(z.shape[-1]):
        from repro.kernels import ops
        return ops.rfft_pallas(z, interpret=engine.interpret)
    return jnp.fft.rfft(z, axis=-1)


def _irfft(c, n, engine):
    if _use_pallas(engine) and _pow2(n):
        from repro.kernels import ops
        return ops.irfft_pallas(c, n, interpret=engine.interpret)
    return jnp.fft.irfft(c, n=n, axis=-1)


def _cfft(z, engine, inverse=False):
    """Engine-aware complex FFT over the last axis (the solver's c2c dirs)."""
    if not jnp.iscomplexobj(z):
        z = z.astype(jnp.complex128 if z.dtype == jnp.float64
                     else jnp.complex64)
    if _use_pallas(engine) and _pow2(z.shape[-1]):
        from repro.kernels import ops
        return ops.fft1d(z, inverse=inverse, interpret=engine.interpret)
    return (jnp.fft.ifft if inverse else jnp.fft.fft)(z, axis=-1)


def _post(re, im, a, b, engine, out_dtype):
    """y = a * re + b * im along the last axis (the r2r post-twiddle)."""
    if _use_pallas(engine):
        from repro.kernels import ops
        return ops.post_twiddle(re, im, a, b,
                                interpret=engine.interpret).astype(out_dtype)
    av = jnp.asarray(a, dtype=out_dtype)
    bv = jnp.asarray(b, dtype=out_dtype)
    return (av * re + bv * im).astype(out_dtype)


# ---------------------------------------------------------------------------
# twiddle tables (plan-time constants, float64; cast at use)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def twiddle_tables(kind: TransformKind, m: int):
    """Precomputed twiddle constants for a size-``m`` transform of ``kind``.

    Keys (all values ``np.float64``):
      post_a/post_b  forward post-twiddle  ``y = a*re + b*im``
      pre_re/pre_im  inverse-family pre-twiddle (2M factor folded in)
      split_c/split_s  type-IV cos/sin input split
    """
    if kind in (TransformKind.DCT1, TransformKind.DST1):
        return {}
    if kind == TransformKind.DCT2:
        k = np.arange(m)
        th = np.pi * k / (2.0 * m)
        return {"post_a": np.cos(th), "post_b": np.sin(th)}
    if kind == TransformKind.DST2:
        k = np.arange(1, m + 1)
        th = np.pi * k / (2.0 * m)
        return {"post_a": np.sin(th), "post_b": -np.cos(th)}
    if kind == TransformKind.DCT3:
        k = np.arange(m)
        th = np.pi * k / (2.0 * m)
        return {"pre_re": 2.0 * m * np.cos(th),
                "pre_im": 2.0 * m * np.sin(th)}
    if kind == TransformKind.DST3:
        k = np.arange(1, m + 1)
        th = np.pi * k / (2.0 * m)
        return {"pre_re": 2.0 * m * np.sin(th),
                "pre_im": -2.0 * m * np.cos(th)}
    if kind in (TransformKind.DCT4, TransformKind.DST4):
        n = np.arange(m)
        b = np.pi * (2 * n + 1) / (4.0 * m)
        return {"split_c": np.cos(b), "split_s": np.sin(b)}
    raise ValueError(kind)


def _tables(kind, m, tables):
    return twiddle_tables(kind, m) if tables is None else tables


# ---------------------------------------------------------------------------
# DCT types
# ---------------------------------------------------------------------------

def dct1(x, engine=None, tables=None):
    """DCT-I: y_k = x_0 + (-1)^k x_{M-1} + 2 sum_{n=1}^{M-2} x_n cos(pi k n/(M-1)).

    Even extension of length 2(M-1); the rfft of a real even signal is real,
    and its M half-spectrum bins are exactly the DCT-I coefficients.
    """
    z = jnp.concatenate([x, x[..., -2:0:-1]], axis=-1)  # even ext, len 2(M-1)
    return _rfft(z, engine).real.astype(_rdtype(x))


def dct2(x, engine=None, tables=None):
    """DCT-II: y_k = 2 sum_n x_n cos(pi k (2n+1) / (2M))."""
    m = x.shape[-1]
    t = _tables(TransformKind.DCT2, m, tables)
    z = jnp.concatenate([x, x[..., ::-1]], axis=-1)     # even ext, len 2M
    f = _rfft(z, engine)[..., :m]
    return _post(f.real, f.imag, t["post_a"], t["post_b"], engine, _rdtype(x))


def dct3(x, engine=None, tables=None):
    """DCT-III: y_k = x_0 + 2 sum_{n=1}^{M-1} x_n cos(pi n (2k+1) / (2M)).

    Pre-twiddle the real input into the hermitian half spectrum whose
    length-2M irfft carries the DCT-III in its first M samples (the 2M
    normalization of irfft is folded into the twiddle table).
    """
    m = x.shape[-1]
    t = _tables(TransformKind.DCT3, m, tables)
    dt = jnp.complex128 if x.dtype == jnp.float64 else jnp.complex64
    c = (x * jnp.asarray(t["pre_re"], x.dtype) +
         1j * (x * jnp.asarray(t["pre_im"], x.dtype))).astype(dt)
    c = jnp.concatenate(
        [c, jnp.zeros(x.shape[:-1] + (1,), dtype=dt)], axis=-1)
    return _irfft(c, 2 * m, engine)[..., :m].astype(_rdtype(x))


def dct4(x, engine=None, tables=None):
    """DCT-IV: y_k = 2 sum_n x_n cos(pi (2k+1)(2n+1) / (4M)).

    Angle-addition split: with c_n = x_n cos(B_n), s_n = x_n sin(B_n) and
    B_n = pi(2n+1)/(4M),  y_k = DCT2(c)_k - DST2(s)_{k-1}  (sine term zero
    at k=0) -- two half-spectrum rffts, no complex intermediates.
    """
    m = x.shape[-1]
    t = _tables(TransformKind.DCT4, m, tables)
    dtype = _rdtype(x)
    c = (x * jnp.asarray(t["split_c"], dtype=dtype)).astype(dtype)
    s = (x * jnp.asarray(t["split_s"], dtype=dtype)).astype(dtype)
    d2 = dct2(c, engine)
    s2 = dst2(s, engine)
    zero = jnp.zeros(x.shape[:-1] + (1,), dtype=dtype)
    return d2 - jnp.concatenate([zero, s2[..., :-1]], axis=-1)


# ---------------------------------------------------------------------------
# DST types
# ---------------------------------------------------------------------------

def dst1(x, engine=None, tables=None):
    """DST-I: y_k = 2 sum_n x_n sin(pi (k+1)(n+1) / (M+1)).

    Odd extension of length 2(M+1); the rfft of a real odd signal is purely
    imaginary, and bins 1..M carry the DST-I coefficients (negated).
    """
    m = x.shape[-1]
    zeros = jnp.zeros(x.shape[:-1] + (1,), dtype=x.dtype)
    # odd extension, length 2(M+1): [0, x, 0, -rev(x)]
    z = jnp.concatenate([zeros, x, zeros, -x[..., ::-1]], axis=-1)
    return (-_rfft(z, engine).imag[..., 1:m + 1]).astype(_rdtype(x))


def dst2(x, engine=None, tables=None):
    """DST-II: y_k = 2 sum_n x_n sin(pi (k+1)(2n+1) / (2M))."""
    m = x.shape[-1]
    t = _tables(TransformKind.DST2, m, tables)
    z = jnp.concatenate([x, -x[..., ::-1]], axis=-1)    # odd ext, len 2M
    f = _rfft(z, engine)[..., 1:m + 1]
    return _post(f.real, f.imag, t["post_a"], t["post_b"], engine, _rdtype(x))


def dst3(x, engine=None, tables=None):
    """DST-III: y_k = (-1)^k x_{M-1} + 2 sum_{n=0}^{M-2} x_n sin(pi (n+1)(2k+1)/(2M)).

    Mirror of dct3: pre-twiddle into bins 1..M of the half spectrum (bin 0
    stays zero), irfft, keep the first M samples.
    """
    m = x.shape[-1]
    t = _tables(TransformKind.DST3, m, tables)
    dt = jnp.complex128 if x.dtype == jnp.float64 else jnp.complex64
    c = (x * jnp.asarray(t["pre_re"], x.dtype) +
         1j * (x * jnp.asarray(t["pre_im"], x.dtype))).astype(dt)
    c = jnp.concatenate(
        [jnp.zeros(x.shape[:-1] + (1,), dtype=dt), c], axis=-1)
    return _irfft(c, 2 * m, engine)[..., :m].astype(_rdtype(x))


def dst4(x, engine=None, tables=None):
    """DST-IV: y_k = 2 sum_n x_n sin(pi (2k+1)(2n+1) / (4M)).

    Split like dct4:  y_k = DCT2(s)_k + DST2(c)_{k-1}  (sine term zero at
    k=0) with the same cos/sin input split.
    """
    m = x.shape[-1]
    t = _tables(TransformKind.DST4, m, tables)
    dtype = _rdtype(x)
    c = (x * jnp.asarray(t["split_c"], dtype=dtype)).astype(dtype)
    s = (x * jnp.asarray(t["split_s"], dtype=dtype)).astype(dtype)
    d2 = dct2(s, engine)
    s2 = dst2(c, engine)
    zero = jnp.zeros(x.shape[:-1] + (1,), dtype=dtype)
    return d2 + jnp.concatenate([zero, s2[..., :-1]], axis=-1)


# ---------------------------------------------------------------------------
# dispatch + normalization
# ---------------------------------------------------------------------------

_FWD = {
    TransformKind.DCT1: dct1, TransformKind.DCT2: dct2,
    TransformKind.DCT3: dct3, TransformKind.DCT4: dct4,
    TransformKind.DST1: dst1, TransformKind.DST2: dst2,
    TransformKind.DST3: dst3, TransformKind.DST4: dst4,
}

_INV = {
    TransformKind.DCT1: dct1, TransformKind.DCT2: dct3,
    TransformKind.DCT3: dct2, TransformKind.DCT4: dct4,
    TransformKind.DST1: dst1, TransformKind.DST2: dst3,
    TransformKind.DST3: dst2, TransformKind.DST4: dst4,
}


def r2r_normfact(kind: TransformKind, m: int) -> float:
    """1 / (forward o backward) amplification for size-m transforms."""
    if kind in (TransformKind.DCT1,):
        return 1.0 / (2.0 * (m - 1))
    if kind in (TransformKind.DST1,):
        return 1.0 / (2.0 * (m + 1))
    return 1.0 / (2.0 * m)


def r2r_forward(x, kind: TransformKind, engine=None, tables=None):
    return _FWD[kind](x, engine=engine, tables=tables)


def r2r_backward(y, kind: TransformKind, engine=None, tables=None):
    """Unnormalized inverse; the solver folds ``r2r_normfact`` into the
    Green's function (standalone callers multiply by it themselves)."""
    return _INV[kind](y, engine=engine, tables=tables)
