"""Legacy full-complex r2r transforms (the pre-half-spectrum reference).

Every DCT/DST here runs a FULL-length complex FFT on the real (anti)symmetric
extension -- 2x the FLOPs and bytes of the half-spectrum algorithm now used by
``repro.core.transforms``.  Kept as a second oracle for the equivalence tests
and as the "old path" baseline in ``benchmarks/bench_kernels.py``; nothing in
the solvers calls this module.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .bc import TransformKind

__all__ = [
    "dct1", "dct2", "dct3", "dct4",
    "dst1", "dst2", "dst3", "dst4",
    "r2r_forward", "r2r_backward", "r2r_normfact",
]


def _rdtype(x):
    return x.dtype


# ---------------------------------------------------------------------------
# DCT types
# ---------------------------------------------------------------------------

def dct1(x):
    """DCT-I: y_k = x_0 + (-1)^k x_{M-1} + 2 sum_{n=1}^{M-2} x_n cos(pi k n/(M-1))."""
    m = x.shape[-1]
    z = jnp.concatenate([x, x[..., -2:0:-1]], axis=-1)  # even ext, len 2(M-1)
    y = jnp.fft.fft(z, axis=-1).real[..., :m]
    return y.astype(_rdtype(x))


def dct2(x):
    """DCT-II: y_k = 2 sum_n x_n cos(pi k (2n+1) / (2M))."""
    m = x.shape[-1]
    z = jnp.concatenate([x, x[..., ::-1]], axis=-1)  # len 2M
    k = jnp.arange(m)
    tw = jnp.exp(-1j * np.pi * k / (2 * m))
    y = (tw * jnp.fft.fft(z, axis=-1)[..., :m]).real
    return y.astype(_rdtype(x))


def dct3(x):
    """DCT-III: y_k = x_0 + 2 sum_{n=1}^{M-1} x_n cos(pi n (2k+1) / (2M))."""
    m = x.shape[-1]
    n = jnp.arange(m)
    c = x * jnp.exp(-1j * np.pi * n / (2 * m))
    cz = jnp.zeros(x.shape[:-1] + (2 * m,), dtype=c.dtype).at[..., :m].set(c)
    y = 2.0 * jnp.fft.fft(cz, axis=-1).real[..., :m] - x[..., 0:1]
    return y.astype(_rdtype(x))


def dct4(x):
    """DCT-IV: y_k = 2 sum_n x_n cos(pi (2k+1)(2n+1) / (4M))."""
    m = x.shape[-1]
    n = jnp.arange(m)
    k = jnp.arange(m)
    c = x * jnp.exp(-1j * np.pi * n / (2 * m))
    cz = jnp.zeros(x.shape[:-1] + (2 * m,), dtype=c.dtype).at[..., :m].set(c)
    f = jnp.fft.fft(cz, axis=-1)[..., :m]
    y = 2.0 * (jnp.exp(-1j * np.pi * (2 * k + 1) / (4 * m)) * f).real
    return y.astype(_rdtype(x))


# ---------------------------------------------------------------------------
# DST types
# ---------------------------------------------------------------------------

def dst1(x):
    """DST-I: y_k = 2 sum_n x_n sin(pi (k+1)(n+1) / (M+1))."""
    m = x.shape[-1]
    zeros = jnp.zeros(x.shape[:-1] + (1,), dtype=x.dtype)
    # odd extension, length 2(M+1): [0, x, 0, -rev(x)]
    z = jnp.concatenate([zeros, x, zeros, -x[..., ::-1]], axis=-1)
    y = -jnp.fft.fft(z, axis=-1).imag[..., 1:m + 1]
    return y.astype(_rdtype(x))


def dst2(x):
    """DST-II: y_k = 2 sum_n x_n sin(pi (k+1)(2n+1) / (2M))."""
    m = x.shape[-1]
    z = jnp.concatenate([x, -x[..., ::-1]], axis=-1)  # len 2M
    k = jnp.arange(1, m + 1)
    f = jnp.fft.fft(z, axis=-1)
    # y_k = Im(i * exp(-i pi j/(2M)) F_j) at j = k+1 ... use j index directly
    fj = jnp.take(f, k, axis=-1)
    y = (1j * jnp.exp(-1j * np.pi * k / (2 * m)) * fj).real
    return y.astype(_rdtype(x))


def dst3(x):
    """DST-III: y_k = (-1)^k x_{M-1} + 2 sum_{n=0}^{M-2} x_n sin(pi (n+1)(2k+1)/(2M))."""
    m = x.shape[-1]
    # w_m coefficients: w_0 = 0, w_j = x_{j-1} (j=1..M-1), w_M = x_{M-1}/2
    zeros = jnp.zeros(x.shape[:-1] + (1,), dtype=x.dtype)
    w = jnp.concatenate(
        [zeros, x[..., :-1], 0.5 * x[..., -1:]], axis=-1)  # len M+1
    jidx = jnp.arange(m + 1)
    wp = w * jnp.exp(1j * np.pi * jidx / (2 * m))
    wz = jnp.zeros(x.shape[:-1] + (2 * m,), dtype=wp.dtype).at[..., :m + 1].set(wp)
    y = 2.0 * (2 * m) * jnp.fft.ifft(wz, axis=-1).imag[..., :m]
    return y.astype(_rdtype(x))


def dst4(x):
    """DST-IV: y_k = 2 sum_n x_n sin(pi (2k+1)(2n+1) / (4M))."""
    m = x.shape[-1]
    n = jnp.arange(m)
    k = jnp.arange(m)
    c = x * jnp.exp(1j * np.pi * n / (2 * m))
    cz = jnp.zeros(x.shape[:-1] + (2 * m,), dtype=c.dtype).at[..., :m].set(c)
    f = (2 * m) * jnp.fft.ifft(cz, axis=-1)[..., :m]
    y = 2.0 * (jnp.exp(1j * np.pi * (2 * k + 1) / (4 * m)) * f).imag
    return y.astype(_rdtype(x))


# ---------------------------------------------------------------------------
# dispatch + normalization
# ---------------------------------------------------------------------------

_FWD = {
    TransformKind.DCT1: dct1, TransformKind.DCT2: dct2,
    TransformKind.DCT3: dct3, TransformKind.DCT4: dct4,
    TransformKind.DST1: dst1, TransformKind.DST2: dst2,
    TransformKind.DST3: dst3, TransformKind.DST4: dst4,
}

_INV = {
    TransformKind.DCT1: dct1, TransformKind.DCT2: dct3,
    TransformKind.DCT3: dct2, TransformKind.DCT4: dct4,
    TransformKind.DST1: dst1, TransformKind.DST2: dst3,
    TransformKind.DST3: dst2, TransformKind.DST4: dst4,
}


def r2r_normfact(kind: TransformKind, m: int) -> float:
    """1 / (forward o backward) amplification for size-m transforms."""
    if kind in (TransformKind.DCT1,):
        return 1.0 / (2.0 * (m - 1))
    if kind in (TransformKind.DST1,):
        return 1.0 / (2.0 * (m + 1))
    return 1.0 / (2.0 * m)


def r2r_forward(x, kind: TransformKind):
    return _FWD[kind](x)


def r2r_backward(y, kind: TransformKind):
    """Unnormalized inverse; caller multiplies by ``r2r_normfact``."""
    return _INV[kind](y)
