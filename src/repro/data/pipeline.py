"""Deterministic, stateless data pipeline.

``batch = batch_for_step(step)`` is a pure function of (seed, step), so any
host can (re)produce any shard at any time -- this is the straggler /
elastic-restart story: no data-loader state to checkpoint, no skew between
replacement hosts (DESIGN.md section 6).

Two sources: ``synthetic`` (hash-derived tokens, always available) and
``memmap`` (a flat token file, split deterministically).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def _threefry_tokens(seed, step, batch, seq, vocab):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    return jax.random.randint(key, (batch, seq + 1), 0, vocab, jnp.int32)


def synthetic_batch(cfg, step, batch, seq, seed=0):
    """Next-token-prediction batch: inputs/labels/mask (+frontend stub)."""
    toks = _threefry_tokens(seed, step, batch, seq, cfg.vocab)
    out = {"inputs": toks[:, :-1], "labels": toks[:, 1:],
           "mask": jnp.ones((batch, seq), jnp.float32)}
    if cfg.n_frontend_tokens:
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 1), step)
        out["frontend"] = jax.random.normal(
            key, (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return out


class MemmapTokens:
    """Flat int32 token file -> deterministic batches by step index."""

    def __init__(self, path, seq_len, dtype=np.int32):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.seq = seq_len
        self.n_seqs = (len(self.data) - 1) // seq_len

    def batch_for_step(self, cfg, step, batch):
        idx = (step * batch + np.arange(batch)) % self.n_seqs
        starts = idx * self.seq
        toks = np.stack([self.data[s:s + self.seq + 1] for s in starts])
        toks = jnp.asarray(toks, jnp.int32)
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:],
                "mask": jnp.ones((batch, self.seq), jnp.float32)}
