"""Distributed pencil-decomposition Poisson solver (shard_map + collectives).

The 2-D process grid (P1, P2) lives on two named mesh axes; every topology
switch is scoped to exactly ONE axis (the paper's sub-communicators).  The
per-direction math is ``repro.core.solver``'s, unchanged; only the axis
shuffles become ``topology_switch`` collectives.

Uneven data counts (the node-centered N+1 problem the paper's Appendix A
load balancing solves for MPI) are handled on TPU by padding the *inactive*
(sharded) axes to a multiple of the mesh axis size: XLA's all-to-all
requires equal splits.  The active axis is always local and exact, so the
transforms, paddings and boundary conventions are identical to the
reference solver.  ``repro.core.partition`` remains the source of truth for
how a real uneven MPI partition would be laid out (and is what the
CPU-cluster deployment path would use).
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.core.bc import DataLayout
from repro.core import green as gr
from repro.core.comm import CommConfig, topology_switch
from repro.core.engine import as_engine, build_schedule
from repro.core.solver import make_plan, build_green, _fwd_1d, _bwd_1d

__all__ = ["DistributedPoissonSolver"]


def _pad_to(n: int, p: int) -> int:
    return -(-n // p) * p


def _pad_dim(x, d, target):
    if x.shape[d] == target:
        return x
    pad = [(0, 0)] * x.ndim
    pad[d] = (0, target - x.shape[d])
    return jnp.pad(x, pad)


def _crop_dim(x, d, target):
    if x.shape[d] == target:
        return x
    sl = [slice(None)] * x.ndim
    sl[d] = slice(0, target)
    return x[tuple(sl)]


class DistributedPoissonSolver:
    """Pencil-distributed flups solve over a (P1, P2) mesh-axis pair.

    ``axes``: the two mesh axis names forming the process grid.
    ``batch_axis``: optional extra mesh axis (e.g. "pod"): the solver then
    takes a leading batch dimension sharded over that axis (data-parallel
    fields, the multi-pod configuration).
    """

    def __init__(self, shape, L, bcs, layout=DataLayout.CELL,
                 green_kind=gr.GreenKind.CHAT2, *, mesh, axes=("data", "model"),
                 comm: CommConfig = CommConfig(), batch_axis=None,
                 eps_factor: float = 2.0, dtype=jnp.float32,
                 lazy_green: bool = False, engine="xla"):
        self.plan = make_plan(shape, L, bcs, layout, green_kind, eps_factor)
        self.engine = as_engine(engine)
        self.schedule = build_schedule(self.plan, self.engine)
        self.mesh = mesh
        self.axes = axes
        self.comm = comm
        self.batch_axis = batch_axis
        self.dtype = dtype
        e = self.plan.order
        d0, d1, d2 = e
        p1 = mesh.shape[axes[0]]
        p2 = mesh.shape[axes[1]]
        dirs = self.plan.dirs
        U = [p.n_pts for p in dirs]
        S = [p.n_out for p in dirs]
        self._U, self._S = U, S
        self._PU1 = _pad_to(U[d1], p1)
        self._PU2 = _pad_to(U[d2], p2)
        self._PS0 = _pad_to(S[d0], p1)
        self._PS1 = _pad_to(S[d1], p2)

        gdtype = np.float64 if dtype == jnp.float64 else np.float32
        gshape = tuple(
            self._PS0 if d == d0 else (self._PS1 if d == d1 else S[d])
            for d in range(3))
        if lazy_green:
            # dry-run: the kernel is an argument, never materialized
            self._green_np = jax.ShapeDtypeStruct(gshape, gdtype)
        else:
            g = build_green(self.plan).astype(gdtype)
            gp = np.zeros(gshape, dtype=gdtype)
            gp[tuple(slice(0, s) for s in g.shape)] = g
            self._green_np = gp

        spec_in = [None, None, None]
        spec_in[d1], spec_in[d2] = axes[0], axes[1]
        spec_g = [None, None, None]
        spec_g[d0], spec_g[d1] = axes[0], axes[1]
        # the Green's function never carries the batch axis (vmap broadcasts
        # it), so its spec is the same with or without batch parallelism
        self.g_spec = P(*spec_g)
        if batch_axis is not None:
            self.in_spec = P(batch_axis, *spec_in)
        else:
            self.in_spec = P(*spec_in)

        local = self._local_solve
        if batch_axis is not None:
            local = jax.vmap(local, in_axes=(0, None))
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:  # jax < 0.6: experimental namespace
            from jax.experimental.shard_map import shard_map
        smap_kw = {}
        if self.engine.use_pallas:
            # pallas_call has no replication rule on older jax releases
            import inspect
            if "check_rep" in inspect.signature(shard_map).parameters:
                smap_kw["check_rep"] = False
        fn = shard_map(
            local, mesh=mesh,
            in_specs=(self.in_spec, self.g_spec),
            out_specs=self.in_spec, **smap_kw)
        self._jit = jax.jit(fn, donate_argnums=(0,))
        self._green_dev = None

    # -- local (per-shard) pipeline ----------------------------------------

    def _local_solve(self, x, green):
        plan = self.plan
        sched = self.schedule
        d0, d1, d2 = plan.order
        dirs = plan.dirs
        a1, a2 = self.axes
        cfg = self.comm
        U, S = self._U, self._S

        x = _fwd_1d(x, dirs[d0], sched)
        x = _pad_dim(x, d0, self._PS0)
        x = topology_switch(x, a1, d0, d1, cfg)
        x = _crop_dim(x, d1, U[d1])
        x = _fwd_1d(x, dirs[d1], sched)
        x = _pad_dim(x, d1, self._PS1)
        x = topology_switch(x, a2, d1, d2, cfg)
        x = _crop_dim(x, d2, U[d2])
        x = _fwd_1d(x, dirs[d2], sched)

        x = sched.green_multiply(x, green)

        x = _bwd_1d(x, dirs[d2], sched)
        x = _pad_dim(x, d2, self._PU2)
        x = topology_switch(x, a2, d2, d1, cfg)
        x = _crop_dim(x, d1, S[d1])
        x = _bwd_1d(x, dirs[d1], sched)
        x = _pad_dim(x, d1, self._PU1)
        x = topology_switch(x, a1, d1, d0, cfg)
        x = _crop_dim(x, d0, S[d0])
        x = _bwd_1d(x, dirs[d0], sched)
        if jnp.iscomplexobj(x):
            x = x.real
        return x.astype(self.dtype)

    # -- public API ----------------------------------------------------------

    @property
    def input_shape(self):
        return self.plan.input_shape

    def padded_input_shape(self, batch=None):
        d0, d1, d2 = self.plan.order
        shp = [0, 0, 0]
        shp[d0] = self._U[d0]
        shp[d1] = self._PU1
        shp[d2] = self._PU2
        shp = tuple(shp)
        return ((batch,) + shp) if batch is not None else shp

    def _pad_input(self, f):
        d0, d1, d2 = self.plan.order
        off = 1 if self.batch_axis is not None else 0
        f = _pad_dim(f, d1 + off, self._PU1)
        f = _pad_dim(f, d2 + off, self._PU2)
        return f

    def green_device(self):
        if self._green_dev is None:
            self._green_dev = jax.device_put(
                self._green_np,
                NamedSharding(self.mesh, self.g_spec))
        return self._green_dev

    def solve(self, f):
        """f: global field (optionally with a leading batch dim)."""
        f = jnp.asarray(f, dtype=self.dtype)
        f = self._pad_input(f)
        f = jax.device_put(f, NamedSharding(self.mesh, self.in_spec))
        out = self._jit(f, self.green_device())
        d0, d1, d2 = self.plan.order
        off = 1 if self.batch_axis is not None else 0
        out = _crop_dim(out, d1 + off, self._U[d1])
        out = _crop_dim(out, d2 + off, self._U[d2])
        return out

    def lower(self, batch=None, dtype=None):
        """Lower the jitted distributed solve with ShapeDtypeStructs (dry-run)."""
        dtype = dtype or self.dtype
        shp = self.padded_input_shape(batch)
        f = jax.ShapeDtypeStruct(shp, dtype,
                                 sharding=NamedSharding(self.mesh, self.in_spec))
        g = jax.ShapeDtypeStruct(self._green_np.shape, self._green_np.dtype,
                                 sharding=NamedSharding(self.mesh, self.g_spec))
        return self._jit.lower(f, g)
