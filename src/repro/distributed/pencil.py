"""Distributed pencil-decomposition Poisson solver (shard_map + collectives).

The 2-D process grid (P1, P2) lives on two named mesh axes; every topology
switch is scoped to exactly ONE axis (the paper's sub-communicators).  The
per-direction math is ``repro.core.engine``'s, unchanged; only the axis
shuffles become ``CommStrategy`` collectives.

The local solve is a software pipeline of fused transform+switch STAGES:
each topology switch carries the next direction's 1-D transform as its
``post`` continuation (``TransformSchedule.fwd_chunk``/``bwd_chunk``), so
the ``overlap`` strategy can interleave chunk k's transform with chunk k+1's
collective -- the paper's non-blocking variants, where shuffle compute hides
wire time.  Monolithic strategies run the same continuation on the whole
switched block, so all strategies share one code path and are numerically
identical.

``comm="auto"`` resolves the strategy at plan time with
``repro.core.comm.autotune_comm`` (the flups switchsort analogue): each
candidate (strategy, n_chunks) pair is compiled and timed for THIS plan's
shapes and mesh, and the winner is cached per (shape, bcs, layout, mesh)
key.

Uneven data counts (the node-centered N+1 problem the paper's Appendix A
load balancing solves for MPI) are handled on TPU by padding the *inactive*
(sharded) axes to a multiple of the mesh axis size: XLA's all-to-all
requires equal splits.  The active axis is always local and exact, so the
transforms, paddings and boundary conventions are identical to the
reference solver.  ``repro.core.partition`` remains the source of truth for
how a real uneven MPI partition would be laid out (and is what the
CPU-cluster deployment path would use).
"""
from __future__ import annotations

import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.core.bc import DataLayout
from repro.core import green as gr
from repro.core.comm import (CommConfig, as_comm, autotune_comm,
                             crop_axis, make_strategy, pad_axis)
from repro.core.engine import as_engine, build_schedule
from repro.core.solver import make_plan, build_green

__all__ = ["DistributedPoissonSolver"]


def _pad_to(n: int, p: int) -> int:
    return -(-n // p) * p


# axis pad/crop shared with the comm chunking layer
_pad_dim = pad_axis
_crop_dim = crop_axis


class DistributedPoissonSolver:
    """Pencil-distributed flups solve over a (P1, P2) mesh-axis pair.

    ``axes``: the two mesh axis names forming the process grid.
    ``batch_axis``: optional extra mesh axis (e.g. "pod"): the solver then
    takes a leading batch dimension sharded over that axis (data-parallel
    fields, the multi-pod configuration).
    ``comm``: a ``CommConfig``, a strategy name, or ``"auto"`` (plan-time
    autotuned; see module docstring).
    """

    def __init__(self, shape, L, bcs, layout=DataLayout.CELL,
                 green_kind=gr.GreenKind.CHAT2, *, mesh, axes=("data", "model"),
                 comm=CommConfig(), batch_axis=None,
                 eps_factor: float = 2.0, dtype=jnp.float32,
                 lazy_green: bool = False, engine="xla",
                 autotune_candidates=None, autotune_cache=None,
                 autotune_batch=None):
        self.plan = make_plan(shape, L, bcs, layout, green_kind, eps_factor)
        self.engine = as_engine(engine)
        self.schedule = build_schedule(self.plan, self.engine)
        self.mesh = mesh
        self.axes = axes
        self.batch_axis = batch_axis
        self.dtype = dtype
        e = self.plan.order
        d0, d1, d2 = e
        p1 = mesh.shape[axes[0]]
        p2 = mesh.shape[axes[1]]
        dirs = self.plan.dirs
        U = [p.n_pts for p in dirs]
        S = [p.n_out for p in dirs]
        self._U, self._S = U, S
        self._PU1 = _pad_to(U[d1], p1)
        self._PU2 = _pad_to(U[d2], p2)
        self._PS0 = _pad_to(S[d0], p1)
        self._PS1 = _pad_to(S[d1], p2)

        gdtype = np.float64 if dtype == jnp.float64 else np.float32
        gshape = tuple(
            self._PS0 if d == d0 else (self._PS1 if d == d1 else S[d])
            for d in range(3))
        if lazy_green:
            # dry-run: the kernel is an argument, never materialized
            self._green_np = jax.ShapeDtypeStruct(gshape, gdtype)
        else:
            g = build_green(self.plan).astype(gdtype)
            gp = np.zeros(gshape, dtype=gdtype)
            gp[tuple(slice(0, s) for s in g.shape)] = g
            self._green_np = gp

        spec_in = [None, None, None]
        spec_in[d1], spec_in[d2] = axes[0], axes[1]
        spec_g = [None, None, None]
        spec_g[d0], spec_g[d1] = axes[0], axes[1]
        # the Green's function never carries the batch axis (vmap broadcasts
        # it), so its spec is the same with or without batch parallelism
        self.g_spec = P(*spec_g)
        if batch_axis is not None:
            self.in_spec = P(batch_axis, *spec_in)
        else:
            self.in_spec = P(*spec_in)
        self._green_dev = None

        if isinstance(comm, str) and comm == "auto":
            self.comm = self._autotune(autotune_candidates, autotune_cache,
                                       autotune_batch)
        else:
            self.comm = as_comm(comm)
        self._jit = self._build_jit(self.comm, donate=True)

    # -- local (per-shard) pipeline ----------------------------------------

    def _local_solve(self, x, green, *, cfg: CommConfig):
        sched = self.schedule
        d0, d1, d2 = self.plan.order
        a1, a2 = self.axes
        U, S = self._U, self._S
        strat = make_strategy(cfg)

        # forward sweep: every switch carries the next direction's transform
        # as its post continuation (crop the gathered axis, then transform)
        x = sched.fwd_chunk(x, d0)
        x = _pad_dim(x, d0, self._PS0)
        x = strat.stage(
            x, a1, d0, d1,
            post=lambda c: sched.fwd_chunk(_crop_dim(c, d1, U[d1]), d1))
        x = _pad_dim(x, d1, self._PS1)
        x = strat.stage(
            x, a2, d1, d2,
            post=lambda c: sched.fwd_chunk(_crop_dim(c, d2, U[d2]), d2))

        x = sched.green_multiply(x, green)

        x = sched.bwd_chunk(x, d2)
        x = _pad_dim(x, d2, self._PU2)
        x = strat.stage(
            x, a2, d2, d1,
            post=lambda c: sched.bwd_chunk(_crop_dim(c, d1, S[d1]), d1))
        x = _pad_dim(x, d1, self._PU1)
        x = strat.stage(
            x, a1, d1, d0,
            post=lambda c: sched.bwd_chunk(_crop_dim(c, d0, S[d0]), d0))
        if jnp.iscomplexobj(x):
            x = x.real
        return x.astype(self.dtype)

    # -- jit assembly --------------------------------------------------------

    def _build_jit(self, cfg: CommConfig, donate: bool):
        """shard_map + jit of the local pipeline under one comm config."""
        local = partial(self._local_solve, cfg=cfg)
        if self.batch_axis is not None:
            local = jax.vmap(local, in_axes=(0, None))
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:  # jax < 0.6: experimental namespace
            from jax.experimental.shard_map import shard_map
        smap_kw = {}
        if self.engine.use_pallas:
            # pallas_call has no replication rule on older jax releases
            import inspect
            if "check_rep" in inspect.signature(shard_map).parameters:
                smap_kw["check_rep"] = False
        fn = shard_map(
            local, mesh=self.mesh,
            in_specs=(self.in_spec, self.g_spec),
            out_specs=self.in_spec, **smap_kw)
        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    # -- plan-time comm autotuner (flups switchsort analogue) ----------------

    def autotune_key(self):
        """Canonical, repr-stable identity of (shape, bcs, layout, mesh)."""
        dirs = self.plan.dirs
        return (
            tuple(p.n for p in dirs),
            tuple((p.bc.left.name, p.bc.right.name) for p in dirs),
            dirs[0].layout.name,
            tuple((a, int(self.mesh.shape[a])) for a in self.mesh.axis_names),
            tuple(self.axes), self.batch_axis,
            jnp.dtype(self.dtype).name, self.engine.name,
        )

    def _autotune(self, candidates, cache_path, batch=None,
                  reps: int = 3) -> CommConfig:
        # timed workload: per-shard batch 1 unless the caller states the
        # production batch (``autotune_batch``); the timed extent is part
        # of the cache key, so differently-sized tunings never collide
        if self.batch_axis is None:
            batch = None
        elif batch is None:
            batch = self.mesh.shape[self.batch_axis]
        fshape = self.padded_input_shape(batch)
        gsd = self._green_np

        def time_cfg(cfg):
            fn = self._build_jit(cfg, donate=False)
            f = jax.device_put(jnp.ones(fshape, self.dtype),
                               NamedSharding(self.mesh, self.in_spec))
            # lazy_green dry-runs autotune against a zero kernel: comm cost
            # does not depend on the Green's values, only its layout
            if isinstance(gsd, jax.ShapeDtypeStruct):
                g = jax.device_put(jnp.zeros(gsd.shape, gsd.dtype),
                                   NamedSharding(self.mesh, self.g_spec))
            else:
                g = self.green_device()
            fn(f, g).block_until_ready()          # compile + warm
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                fn(f, g).block_until_ready()
                best = min(best, time.perf_counter() - t0)
            return best

        self.autotune_results = {}
        key = self.autotune_key() + (("tuned_batch", batch),)
        return autotune_comm(key, time_cfg,
                             candidates=candidates, cache_path=cache_path,
                             results=self.autotune_results)

    # -- public API ----------------------------------------------------------

    @property
    def input_shape(self):
        return self.plan.input_shape

    def padded_input_shape(self, batch=None):
        d0, d1, d2 = self.plan.order
        shp = [0, 0, 0]
        shp[d0] = self._U[d0]
        shp[d1] = self._PU1
        shp[d2] = self._PU2
        shp = tuple(shp)
        return ((batch,) + shp) if batch is not None else shp

    def _pad_input(self, f):
        d0, d1, d2 = self.plan.order
        off = 1 if self.batch_axis is not None else 0
        f = _pad_dim(f, d1 + off, self._PU1)
        f = _pad_dim(f, d2 + off, self._PU2)
        return f

    def green_device(self):
        if self._green_dev is None:
            self._green_dev = jax.device_put(
                self._green_np,
                NamedSharding(self.mesh, self.g_spec))
        return self._green_dev

    def solve(self, f):
        """f: global field (optionally with a leading batch dim)."""
        f = jnp.asarray(f, dtype=self.dtype)
        f = self._pad_input(f)
        f = jax.device_put(f, NamedSharding(self.mesh, self.in_spec))
        out = self._jit(f, self.green_device())
        d0, d1, d2 = self.plan.order
        off = 1 if self.batch_axis is not None else 0
        out = _crop_dim(out, d1 + off, self._U[d1])
        out = _crop_dim(out, d2 + off, self._U[d2])
        return out

    def lower(self, batch=None, dtype=None):
        """Lower the jitted distributed solve with ShapeDtypeStructs (dry-run)."""
        dtype = dtype or self.dtype
        shp = self.padded_input_shape(batch)
        f = jax.ShapeDtypeStruct(shp, dtype,
                                 sharding=NamedSharding(self.mesh, self.in_spec))
        g = jax.ShapeDtypeStruct(self._green_np.shape, self._green_np.dtype,
                                 sharding=NamedSharding(self.mesh, self.g_spec))
        return self._jit.lower(f, g)
