"""Distributed pencil-decomposition Poisson solver (shard_map + collectives).

The 2-D process grid (P1, P2) lives on two named mesh axes; every topology
switch is scoped to exactly ONE axis (the paper's sub-communicators).  The
per-direction math is ``repro.core.engine``'s, unchanged; only the axis
shuffles become ``CommStrategy`` collectives.

The local solve is a software pipeline of fused transform+switch STAGES:
each topology switch carries the next direction's 1-D transform as its
``post`` continuation (``TransformSchedule.fwd_chunk``/``bwd_chunk``), so
the ``overlap`` strategy can interleave chunk k's transform with chunk k+1's
collective -- the paper's non-blocking variants, where shuffle compute hides
wire time.  Monolithic strategies run the same continuation on the whole
switched block, so all strategies share one code path and are numerically
identical.

Every stage is VALID-EXTENT aware (DESIGN.md #8): the split axis's live
extent (``Plan1D.valid_in``/``n_out``) is handed to
``CommStrategy.stage(valid_extent=...)``, which crops and re-pads to the
equal-split multiple internally.  Under the default ``doubling="deferred"``
the Hockney zero extension of unbounded directions exists only inside each
direction's own 1-D transform, so the early switches ship the n-point
physical axes; ``doubling="upfront"`` materializes the doubling in the
input field (the dense baseline ``benchmarks/bench_solve.py`` measures
against).

``comm="auto"`` resolves the strategy at plan time with
``repro.core.comm.autotune_comm`` (the flups switchsort analogue): each
candidate (strategy, n_chunks) pair is compiled and timed for THIS plan's
shapes and mesh, and the winner is cached per (shape, bcs, layout, mesh)
key.

Uneven data counts (the node-centered N+1 problem the paper's Appendix A
load balancing solves for MPI) are handled on TPU by padding the *inactive*
(sharded) axes to a multiple of the mesh axis size: XLA's all-to-all
requires equal splits.  The active axis is always local and exact, so the
transforms, paddings and boundary conventions are identical to the
reference solver.  ``repro.core.partition`` remains the source of truth for
how a real uneven MPI partition would be laid out (and is what the
CPU-cluster deployment path would use).
"""
from __future__ import annotations

import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.core.bc import DataLayout
from repro.core import green as gr
from repro.core.comm import (CommConfig, as_comm, autotune_comm,
                             autotune_candidates as _default_candidates,
                             crop_axis, make_strategy, pad_axis)
from repro.core.engine import (RELAYOUT_MODES, as_engine, build_schedule,
                               relayout)
from repro.core.solver import make_plan, build_green

__all__ = ["DistributedPoissonSolver"]


def _pad_to(n: int, p: int) -> int:
    return -(-n // p) * p


# axis pad/crop shared with the comm chunking layer
_pad_dim = pad_axis
_crop_dim = crop_axis


class DistributedPoissonSolver:
    """Pencil-distributed flups solve over a (P1, P2) mesh-axis pair.

    ``axes``: the two mesh axis names forming the process grid.
    ``batch_axis``: optional extra mesh axis (e.g. "pod"): the solver then
    takes a leading batch dimension sharded over that axis (data-parallel
    fields, the multi-pod configuration).
    ``comm``: a ``CommConfig``, a strategy name, or ``"auto"`` (plan-time
    autotuned; see module docstring).
    ``relayout``: ``"scheduled"`` (default; plan-time ``LayoutSchedule``,
    relayouts folded into the topology switches -- DESIGN.md #9) or
    ``"baseline"`` (per-direction moveaxis round trips, the A/B
    reference).  Bit-exact vs each other on the XLA engine.
    ``order_policy``: ``"layout"`` (default; the execution order within
    each BC category is chosen to minimize edge relayouts) or
    ``"natural"`` (historical ascending order -- with
    ``relayout="baseline"`` this reproduces the PR-4 pipeline exactly).

    Batched multi-RHS execution: ``solve`` also accepts ``f`` with ONE
    extra leading batch dimension carried in-block (replicated over the
    mesh, not sharded): ``(B, *grid)``, or ``(B_pod, B, *grid)`` when
    ``batch_axis`` is set.  All B right-hand sides ride through the same
    topology switches -- same number of collectives, B-fold payload -- and
    the chunked comm strategies treat the batch axis as a free chunk axis
    (no zero-padding when ``B % n_chunks == 0``).  One jit specialization
    exists per input rank; plan, Green and autotuned comm are shared.
    """

    def __init__(self, shape, L, bcs, layout=DataLayout.CELL,
                 green_kind=gr.GreenKind.CHAT2, *, mesh, axes=("data", "model"),
                 comm=CommConfig(), batch_axis=None,
                 eps_factor: float = 2.0, dtype=jnp.float32,
                 lazy_green: bool = False, engine="xla",
                 doubling: str = "deferred", relayout: str = "scheduled",
                 order_policy: str = "layout",
                 autotune_candidates=None, autotune_cache=None,
                 autotune_batch=None, autotune_budget=None,
                 autotune_search: str = "guided",
                 verify=None, verify_rtol=0.5, abft_rtol=0.0,
                 _green_cache=None):
        assert relayout in RELAYOUT_MODES, relayout
        assert verify in (None, "nan", "residual", "abft",
                          "abft-stages"), verify
        assert autotune_search in ("guided", "brute"), autotune_search
        # full construction identity, kept for _configure (ladder rebuilds)
        # and rebuild(mesh) (elastic recovery re-plans)
        self._ctor = dict(shape=tuple(shape), L=L, bcs=bcs, layout=layout,
                          green_kind=green_kind, axes=tuple(axes),
                          batch_axis=batch_axis, eps_factor=eps_factor,
                          dtype=dtype, lazy_green=lazy_green,
                          order_policy=order_policy, comm_req=comm,
                          engine_obj=as_engine(engine),
                          autotune_candidates=autotune_candidates,
                          autotune_cache=autotune_cache,
                          autotune_batch=autotune_batch,
                          autotune_budget=autotune_budget,
                          autotune_search=autotune_search)
        self.verify = verify
        self.verify_rtol = float(verify_rtol)
        # ABFT checksum tolerance; 0.0 = auto per data dtype (abft.tol_for)
        self.abft_rtol = float(abft_rtol)
        self.stats = {"solves": 0, "retries": 0, "verify_failures": 0,
                      "degradations": []}
        self.mesh = mesh
        self.axes = tuple(axes)
        self.batch_axis = batch_axis
        self.dtype = dtype
        # raw (unpadded, natural-layout, f64) transformed Green: computed
        # once and reused across ladder rebuilds AND elastic rebuilds --
        # the O(N^3) assembly never reruns on a recovery path
        self._green_raw = _green_cache
        self._configure({"engine": as_engine(engine).name, "comm": None,
                         "doubling": doubling, "relayout": relayout})

    def _configure(self, cfg: dict):
        """(Re)build plan, Green layout, comm strategy and jits for one
        runtime config (the degradation ladder's rebuild hook).  The first
        build (``cfg["comm"] is None``) resolves the user's comm request
        (possibly ``"auto"`` -- the plan-time tuner); ladder rebuilds carry
        the degraded strategy name and keep n_chunks/fold."""
        c = self._ctor
        shape, L, bcs = c["shape"], c["L"], c["bcs"]
        layout, green_kind = c["layout"], c["green_kind"]
        eps_factor, order_policy = c["eps_factor"], c["order_policy"]
        lazy_green, dtype = c["lazy_green"], c["dtype"]
        axes, mesh = self.axes, self.mesh
        self._cfg = dict(cfg)
        self.plan = make_plan(shape, L, bcs, layout, green_kind, eps_factor,
                              doubling=cfg["doubling"],
                              order_policy=order_policy)
        # keep the constructor's engine OBJECT (it may carry a non-default
        # max_radix) as long as the ladder has not degraded the engine name
        base_eng = c.get("engine_obj")
        self.engine = (base_eng if base_eng is not None
                       and base_eng.name == cfg["engine"]
                       else as_engine(cfg["engine"]))
        self.schedule = build_schedule(self.plan, self.engine)
        self.relayout = cfg["relayout"]
        e = self.plan.order
        d0, d1, d2 = e
        p1 = mesh.shape[axes[0]]
        p2 = mesh.shape[axes[1]]
        self._axis_sizes = {axes[0]: p1, axes[1]: p2}
        dirs = self.plan.dirs
        # per-dim live physical extent OUTSIDE the dim's own transform:
        # n_pts under deferred (pruned) doubling, n_fft when padded up front
        U = [p.valid_in for p in dirs]
        S = [p.n_out for p in dirs]
        self._U, self._S = U, S
        self._PU1 = _pad_to(U[d1], p1)
        self._PU2 = _pad_to(U[d2], p2)
        self._PS0 = _pad_to(S[d0], p1)
        self._PS1 = _pad_to(S[d1], p2)

        gdtype = np.float64 if dtype == jnp.float64 else np.float32
        gshape = tuple(
            self._PS0 if d == d0 else (self._PS1 if d == d1 else S[d])
            for d in range(3))
        # layout-scheduled pipelines hold the spectral block in the layout
        # the LAST forward stage leaves it in (active axis minor-most); the
        # Green's function is materialized directly in that layout at plan
        # time, so the pointwise multiply never relayouts anything
        gperm = (self.schedule.layouts.spectral
                 if self.relayout == "scheduled" else (0, 1, 2))
        if lazy_green:
            # dry-run: the kernel is an argument, never materialized
            self._green_np = jax.ShapeDtypeStruct(
                tuple(gshape[d] for d in gperm), gdtype)
        else:
            if self._green_raw is None:
                self._green_raw = build_green(self.plan)
            g = self._green_raw.astype(gdtype)
            gp = np.zeros(gshape, dtype=gdtype)
            gp[tuple(slice(0, s) for s in g.shape)] = g
            self._green_np = np.ascontiguousarray(np.transpose(gp, gperm))

        spec_in = [None, None, None]
        spec_in[d1], spec_in[d2] = axes[0], axes[1]
        self._spec_in_tail = tuple(spec_in)
        spec_g = [None, None, None]
        spec_g[d0], spec_g[d1] = axes[0], axes[1]
        # the Green's function never carries the batch axis (vmap broadcasts
        # it), so its spec is the same with or without batch parallelism
        self.g_spec = P(*(spec_g[d] for d in gperm))
        self.in_spec = self.input_spec(local_batch=False)
        self._green_dev = None

        if cfg["comm"] is None:
            # first build: resolve the user's request (incl. "auto")
            comm_req = c["comm_req"]
            if isinstance(comm_req, str) and comm_req == "auto":
                self.comm = self._autotune(c["autotune_candidates"],
                                           c["autotune_cache"],
                                           c["autotune_batch"],
                                           budget=c["autotune_budget"])
            else:
                self.comm = as_comm(comm_req)
            self._cfg["comm"] = self.comm.strategy
        elif getattr(self, "comm", None) is None \
                or cfg["comm"] != self.comm.strategy:
            # ladder rebuild: degraded strategy, n_chunks/fold carried over
            prev = getattr(self, "comm", None) or CommConfig()
            nc = prev.n_chunks if cfg["comm"] in ("pipelined", "overlap") \
                else 1
            self.comm = CommConfig(cfg["comm"], max(nc, 1), prev.fold,
                                   prev.chunk_axis)
        self._green_dev = None
        self._jits = {}
        # checked (verify="abft-stages" / localization) traces live apart
        # from the clean jits: they emit checksum sandwiches, sidecar
        # collectives and a report output, so the clean path stays
        # bit-exact with checks compiled out.  verify="abft" shares the
        # clean jits -- its sandwich is entirely host-side -- and
        # ``_lite_weights`` holds the plan-time Freivalds material
        # (rank-1 probe factors, w = S^T C^T r)
        self._abft_jits = {}
        self._lite_weights = {}
        self._jit = self.jit_for(local_batch=False)

    # -- local (per-shard) pipeline ----------------------------------------

    def _local_solve(self, x, green, *, cfg: CommConfig,
                     col=None, tol=None):
        sched = self.schedule
        d0, d1, d2 = self.plan.order
        a1, a2 = self.axes
        U, S = self._U, self._S
        strat = make_strategy(cfg, axis_sizes=self._axis_sizes,
                              abft=None if col is None else (col, tol))
        # leading batch axes (multi-RHS) shift every grid-dim index; they
        # are also the chunked strategies' preferred (free) chunk axis --
        # unless the config pins the uninvolved grid axis (chunk_axis="grid")
        off = x.ndim - len(self.plan.dirs)
        ca = 0 if off and cfg.chunk_axis == "auto" else None
        e0, e1, e2 = d0 + off, d1 + off, d2 + off

        # forward sweep: every switch carries the next direction's transform
        # as its post continuation (crop the gathered axis, then transform).
        # ``valid_extent`` is the split axis's live extent (deferred-doubling
        # pruning: the first switches ship the n-point physical axes, never
        # a 2n Hockney extension); the strategy crops + re-pads to the
        # equal-split multiple internally.
        x = sched.fwd_chunk(x, d0, col, tol)
        x = strat.stage(
            x, a1, e0, e1, chunk_axis=ca, valid_extent=S[d0],
            post=lambda c: sched.fwd_chunk(_crop_dim(c, e1, U[d1]), d1,
                                           col, tol))
        x = strat.stage(
            x, a2, e1, e2, chunk_axis=ca, valid_extent=S[d1],
            post=lambda c: sched.fwd_chunk(_crop_dim(c, e2, U[d2]), d2,
                                           col, tol))

        x = sched.green_multiply(x, green, col, tol)

        x = sched.bwd_chunk(x, d2, col, tol)
        x = strat.stage(
            x, a2, e2, e1, chunk_axis=ca, valid_extent=U[d2],
            post=lambda c: sched.bwd_chunk(_crop_dim(c, e1, S[d1]), d1,
                                           col, tol))
        x = strat.stage(
            x, a1, e1, e0, chunk_axis=ca, valid_extent=U[d1],
            post=lambda c: sched.bwd_chunk(_crop_dim(c, e0, S[d0]), d0,
                                           col, tol))
        if jnp.iscomplexobj(x):
            x = x.real
        return x.astype(self.dtype)

    def _local_solve_scheduled(self, x, green, *, cfg: CommConfig,
                               col=None, tol=None):
        """The layout-SCHEDULED local pipeline (DESIGN.md #9): every stage
        keeps its active axis minor-most, so the 1-D transforms move no
        data, and the single relayout between consecutive directions is
        folded into the topology switch's pack (``permute=``) -- after it
        the collective always splits the retiring dim as a contiguous
        MAJOR axis and gathers the incoming dim straight into the
        minor-most slot the next transform consumes.  The only standalone
        transposes left are the two edge adapters (natural user layout in,
        natural layout out) -- asserted on lowered HLO via
        ``hlo_stats.transpose_stats``.  Numerically identical to
        ``_local_solve`` (bit-exact on the XLA engine: transposes reorder
        rows, the per-row transform and pointwise math is unchanged).
        """
        sched = self.schedule
        d0, d1, d2 = self.plan.order
        a1, a2 = self.axes
        U, S = self._U, self._S
        lay = sched.layouts
        L0, L1, L2 = lay.fwd
        B0, B1, B2 = lay.bwd                 # B0 == L2 (spectral layout)
        strat = make_strategy(cfg, axis_sizes=self._axis_sizes,
                              abft=None if col is None else (col, tol))
        off = x.ndim - len(self.plan.dirs)
        ca = 0 if off and cfg.chunk_axis == "auto" else None
        nat = tuple(range(len(self.plan.dirs)))
        first, last = off, x.ndim - 1        # switch frame: split major,
                                             # gather minor (switch_layout)

        def pm(src, dst):
            # transpose spec (full array rank) folded into the pack
            return (tuple(range(off))
                    + tuple(off + src.index(d) for d in dst))

        x = relayout(x, nat, L0)             # edge adapter (identity when
                                             # d0 is already minor-most)
        x = sched.fwd_last(x, d0, col, tol)
        x = strat.stage(
            x, a1, first, last, chunk_axis=ca,
            valid_extent=S[d0], permute=pm(L0, L1),
            post=lambda c: sched.fwd_last(_crop_dim(c, last, U[d1]), d1,
                                          col, tol))
        if col is None and sched.can_fuse_green(d2):
            # Pallas: the last forward FFT runs the Green multiply in its
            # final-stage registers -- the stage continuation only crops,
            # the fused kernel runs on the whole switched block
            x = strat.stage(
                x, a2, first, last, chunk_axis=ca,
                valid_extent=S[d1], permute=pm(L1, L2),
                post=lambda c: _crop_dim(c, last, U[d2]))
            x = sched.fwd_last_green(x, d2, green)
        else:
            x = strat.stage(
                x, a2, first, last, chunk_axis=ca,
                valid_extent=S[d1], permute=pm(L1, L2),
                post=lambda c: sched.fwd_last(_crop_dim(c, last, U[d2]), d2,
                                              col, tol))
            x = sched.green_multiply(x, green, col, tol)

        x = sched.bwd_last(x, d2, col, tol)  # spectral layout: d2 last
        x = strat.stage(
            x, a2, first, last, chunk_axis=ca,
            valid_extent=U[d2], permute=pm(B0, B1),
            post=lambda c: sched.bwd_last(_crop_dim(c, last, S[d1]), d1,
                                          col, tol))
        x = strat.stage(
            x, a1, first, last, chunk_axis=ca,
            valid_extent=U[d1], permute=pm(B1, B2),
            post=lambda c: sched.bwd_last(_crop_dim(c, last, S[d0]), d0,
                                          col, tol))
        x = relayout(x, B2, nat)             # edge adapter back
        if jnp.iscomplexobj(x):
            x = x.real
        return x.astype(self.dtype)

    # -- jit assembly --------------------------------------------------------

    def input_spec(self, local_batch: bool = False) -> P:
        """PartitionSpec of the input field: optional pod-sharded batch,
        optional replicated in-block batch, then the pencil grid."""
        parts = []
        if self.batch_axis is not None:
            parts.append(self.batch_axis)
        if local_batch:
            parts.append(None)
        return P(*parts, *self._spec_in_tail)

    def jit_for(self, local_batch: bool = False, donate: bool = True):
        """The jitted distributed solve for one input rank (cached).

        The cache key includes the active fault-plan token, so arming a
        ``FaultPlan`` forces a retrace (the trace-time taint/fail_point
        hooks run) and a tainted trace never shadows the clean entry."""
        from repro.runtime import faults
        key = (bool(local_batch), bool(donate), faults.plan_token())
        fn = self._jits.get(key)
        if fn is None:
            fn = self._build_jit(self.comm, donate=donate,
                                 local_batch=local_batch)
            self._jits[key] = fn
        return fn

    def _build_jit(self, cfg: CommConfig, donate: bool,
                   local_batch: bool = False):
        """shard_map + jit of the local pipeline under one comm config."""
        body = (self._local_solve_scheduled if self.relayout == "scheduled"
                else self._local_solve)
        local = partial(body, cfg=cfg)
        if self.batch_axis is not None:
            local = jax.vmap(local, in_axes=(0, None))
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:  # jax < 0.6: experimental namespace
            from jax.experimental.shard_map import shard_map
        smap_kw = {}
        if self.engine.use_pallas:
            # pallas_call has no replication rule on older jax releases
            import inspect
            if "check_rep" in inspect.signature(shard_map).parameters:
                smap_kw["check_rep"] = False
        in_spec = self.input_spec(local_batch)
        fn = shard_map(
            local, mesh=self.mesh,
            in_specs=(in_spec, self.g_spec),
            out_specs=in_spec, **smap_kw)
        return jax.jit(fn, donate_argnums=(0,) if donate else ())

    def _abft_tol(self) -> float:
        from repro.runtime import abft
        return self.abft_rtol or abft.tol_for(self.dtype)

    def abft_jit_for(self, local_batch: bool = False):
        """The CHECKED distributed solve (``verify="abft"``): returns
        ``(fn, names)`` where ``fn(f, green) -> (u, report)``.  The local
        body runs with an ``abft.Collector`` threaded through every
        transform stage and topology switch (the comm strategy ships the
        checksum sidecars), each shard's mismatch vector is max-combined
        across both pencil axes with ``lax.pmax``, and the stage names are
        captured into ``names`` at trace time."""
        from repro.runtime import faults
        key = (bool(local_batch), faults.plan_token())
        ent = self._abft_jits.get(key)
        if ent is None:
            ent = self._abft_jits[key] = self._build_abft_jit(
                self.comm, local_batch=local_batch)
        return ent

    def _build_abft_jit(self, cfg: CommConfig, local_batch: bool = False):
        from repro.runtime import abft
        body = (self._local_solve_scheduled if self.relayout == "scheduled"
                else self._local_solve)
        a1, a2 = self.axes
        tol = self._abft_tol()
        holder: list = []

        def local(x, green):
            col = abft.Collector()
            y = body(x, green, cfg=cfg, col=col, tol=tol)
            # every rank checks its own rows; one pmax per axis folds the
            # mesh's K-vector reports into a replicated worst-case vector
            rep = col.stacked()
            rep = jax.lax.pmax(jax.lax.pmax(rep, a1), a2)
            holder[:] = col.names
            return y, rep

        if self.batch_axis is not None:
            # pod-sharded batch: each batch element keeps its own report
            # row ((B, K) global); the host audits the max over rows
            local = jax.vmap(local, in_axes=(0, None))
            rep_spec = P(self.batch_axis, None)
        else:
            rep_spec = P()
        shard_map = getattr(jax, "shard_map", None)
        if shard_map is None:  # jax < 0.6: experimental namespace
            from jax.experimental.shard_map import shard_map
        smap_kw = {}
        import inspect
        if "check_rep" in inspect.signature(shard_map).parameters:
            # the report is replicated by construction (pmax over both
            # axes); skip the replication checker, it cannot see that
            smap_kw["check_rep"] = False
        in_spec = self.input_spec(local_batch)
        fn = shard_map(
            local, mesh=self.mesh,
            in_specs=(in_spec, self.g_spec),
            out_specs=(in_spec, rep_spec), **smap_kw)
        return jax.jit(fn, donate_argnums=(0,)), holder

    def _lite_pair(self, fp_shape, local_batch: bool):
        """Plan-time Freivalds material for one padded input signature:
        rank-1 probe factors ``q0, q1, q2`` over the USER grid and the
        host copy of the weight ``w = S^T C^T r`` -- one vjp of the
        linear distributed solve with the probe zero-embedded through the
        output crop ``C``, traced under fault suppression -- restricted
        to the valid input corner.  Both sandwich sides then run on the
        HOST: ``<r, u>`` is three chained BLAS contractions of the
        cropped output against the factors, ``<w, f>`` one dot against
        the raw user field, and the device pipeline is the SAME jit as
        ``verify=off`` -- zero graph changes, zero extra collectives (on
        a host-device mesh, in-graph scalar plumbing costs more in op
        dispatch than the reductions themselves).  Returns None when the
        sandwich is unavailable -- lazy-green dry runs (no real kernel to
        differentiate through) or an engine whose kernels carry no vjp
        rules -- and ``solve`` falls back to the checked pipeline."""
        from repro.runtime import abft, faults
        key = tuple(fp_shape)
        if key in self._lite_weights:
            return self._lite_weights[key]
        rw = None
        if not self._ctor["lazy_green"]:
            sh = NamedSharding(self.mesh, self.input_spec(local_batch))
            user_grid = tuple(p.n_pts for p in self.plan.dirs)
            qs = abft.lite_probe_axes(user_grid, self.dtype)
            # cotangent: the rank-1 probe over the user grid, zero-padded
            # into the padded output shape (probing the CROPPED output --
            # corruption confined to cropped-away padding cannot reach
            # the solution and needs no alarm)
            r_user = np.einsum("i,j,k->ijk", *qs)
            r_pad = np.zeros(fp_shape, r_user.dtype)
            r_pad[(Ellipsis,) + tuple(slice(0, m) for m in user_grid)] = \
                r_user
            r = jax.device_put(r_pad, sh)
            zero = jax.device_put(
                np.zeros(fp_shape, jnp.dtype(self.dtype)), sh)
            base = self._build_jit(self.comm, donate=False,
                                   local_batch=local_batch)
            try:
                with faults.suppressed():
                    w = jax.jit(lambda rr, gg, z: jax.vjp(
                        lambda x: base(x, gg), z)[1](rr)[0])(
                            r, self.green_device(), zero)
                    jax.block_until_ready(w)
                # padding is zeros, so <w, pad(f)> == <w_valid, f>: keep
                # only the valid corner, in the solve dtype -- the host
                # dot is then one BLAS sdot/ddot with no conversion pass
                wh = np.asarray(w)
                valid = (Ellipsis,) + tuple(
                    slice(0, m) for m in user_grid)
                wv = np.ascontiguousarray(wh[valid])
                wf = wv.reshape(wv.shape[:-3] + (-1,)).astype(np.float64)
                wn = np.sqrt(np.einsum("...i,...i->...", wf, wf))
                rw = (qs, wv, wn)
            except NotImplementedError:
                # an engine kernel without a differentiation rule (pallas):
                # no sandwich weight; verify="abft" degrades to the checked
                # pipeline for this config
                rw = None
        self._lite_weights[key] = rw
        return rw

    # -- plan-time comm autotuner (flups switchsort analogue) ----------------

    def autotune_key(self):
        """Canonical, repr-stable identity of (shape, bcs, layout, mesh).

        ``doubling`` is part of the identity: a pruned (deferred) plan and a
        dense (up-front) plan ship different extents through every switch,
        so a persisted winner for one must never be replayed for the other
        (the $REPRO_COMM_CACHE staleness guard, tested in test_comm.py).
        """
        dirs = self.plan.dirs
        eng = self.engine.name + ("" if self.engine.max_radix == 4
                                  else f"@r{self.engine.max_radix}")
        return (
            tuple(p.n for p in dirs),
            tuple((p.bc.left.name, p.bc.right.name) for p in dirs),
            dirs[0].layout.name,
            tuple((a, int(self.mesh.shape[a])) for a in self.mesh.axis_names),
            tuple(self.axes), self.batch_axis,
            jnp.dtype(self.dtype).name, eng,
            ("doubling", self.plan.doubling),
            # the layout schedule changes what every candidate compiles to
            # (relayouts folded into the switches vs standalone moveaxis,
            # and the execution order the layouts were chosen for), so the
            # tuner must time what will actually run
            ("relayout", self.relayout),
            ("order", self.plan.order),
        )

    def comm_time_fn(self, batch=None, reps: int = 3):
        """``time_fn(cfg) -> seconds`` over THIS solver's plan/mesh: build
        the jitted pipeline under one comm config, compile + warm, return
        the best of ``reps`` wall-clock solves.  What the autotuner (and
        the guided-vs-brute oracle tests / ``bench_comm.py --search``)
        time candidates with.  ``batch`` follows ``_autotune``'s
        convention: the pod-sharded extent when ``batch_axis`` is set,
        else the in-block multi-RHS extent (None = unbatched)."""
        local_batch = self.batch_axis is None and batch is not None
        fshape = self.padded_input_shape(batch)
        gsd = self._green_np
        in_spec = self.input_spec(local_batch)

        def time_cfg(cfg):
            fn = self._build_jit(cfg, donate=False, local_batch=local_batch)
            f = jax.device_put(jnp.ones(fshape, self.dtype),
                               NamedSharding(self.mesh, in_spec))
            # lazy_green dry-runs autotune against a zero kernel: comm cost
            # does not depend on the Green's values, only its layout
            if isinstance(gsd, jax.ShapeDtypeStruct):
                g = jax.device_put(jnp.zeros(gsd.shape, gsd.dtype),
                                   NamedSharding(self.mesh, self.g_spec))
            else:
                g = self.green_device()
            fn(f, g).block_until_ready()          # compile + warm
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                fn(f, g).block_until_ready()
                best = min(best, time.perf_counter() - t0)
            return best

        return time_cfg

    def _autotune(self, candidates, cache_path, batch=None,
                  reps: int = 3, budget=None) -> CommConfig:
        # timed workload must match the production rank: the pod-sharded
        # batch (default: the pod mesh extent) when ``batch_axis`` is set,
        # or the IN-BLOCK multi-RHS batch when the caller states it
        # (``autotune_batch`` on a 2-axis mesh) -- otherwise the tuner
        # would time the unbatched pipeline and could cache an n_chunks
        # that does not divide B, silently losing the free batch-axis
        # chunking in production.  The timed extent is part of the cache
        # key, so differently-sized tunings never collide.
        if self.batch_axis is not None and batch is None:
            batch = self.mesh.shape[self.batch_axis]
        time_cfg = self.comm_time_fn(batch, reps=reps)
        self.autotune_results = {}
        self.autotune_census = {}
        if candidates is None:
            # layout-scheduled plans also sweep the relayout fold side:
            # whether the switch-fused transpose is cheaper on the pack or
            # the unpack side of the collective is shape-dependent
            folds = (("pack", "unpack") if self.relayout == "scheduled"
                     else ("pack",))
            if self._ctor.get("autotune_search", "guided") == "guided":
                # DESIGN.md #12: rank the comm sub-space with the analytic
                # cost model and hand only the shortlisted frontier to the
                # timer.  The shortlist labels are cache-key material, so
                # a guided pick never shadows (or replays) a brute one.
                from repro.plan.search import guided_comm_candidates
                p1 = self.mesh.shape[self.axes[0]]
                p2 = self.mesh.shape[self.axes[1]]
                in_block = batch if self.batch_axis is None else None
                candidates = guided_comm_candidates(
                    self.plan, p1, p2, self.dtype, batch=in_block,
                    folds=folds, relayout=self.relayout,
                    max_radix=self.engine.max_radix,
                    census=self.autotune_census)
            else:
                candidates = _default_candidates(folds=folds)
        key = self.autotune_key() + (("tuned_batch", batch),)
        return autotune_comm(key, time_cfg,
                             candidates=candidates, cache_path=cache_path,
                             results=self.autotune_results,
                             budget_s=budget, census=self.autotune_census)

    # -- public API ----------------------------------------------------------

    @property
    def input_shape(self):
        return self.plan.input_shape

    def padded_input_shape(self, batch=None):
        d0, d1, d2 = self.plan.order
        shp = [0, 0, 0]
        shp[d0] = self._U[d0]
        shp[d1] = self._PU1
        shp[d2] = self._PU2
        shp = tuple(shp)
        return ((batch,) + shp) if batch is not None else shp

    def _pad_input(self, f):
        from repro.core.engine import materialize_doubling
        d0, d1, d2 = self.plan.order
        off = f.ndim - 3
        # dense (up-front) plans materialize the Hockney zero extension in
        # the global field before the mesh-divisibility padding; deferred
        # plans skip this and every switch ships the n-point extents
        f = materialize_doubling(f, self.plan.dirs)
        f = _pad_dim(f, d1 + off, self._PU1)
        f = _pad_dim(f, d2 + off, self._PU2)
        return f

    def green_device(self):
        if self._green_dev is None:
            self._green_dev = jax.device_put(
                self._green_np,
                NamedSharding(self.mesh, self.g_spec))
        return self._green_dev

    def _dispatch(self, f, local_batch: bool, abft: bool = False,
                  lite: bool = False):
        """One solve attempt under the CURRENT config: pad, shard, run the
        jitted pipeline, crop.  Re-entered by the degradation ladder after
        ``_configure`` rebuilds -- padded extents/specs may differ per rung,
        so everything derives from the raw user array each attempt.  Under
        ``abft`` the checked jit runs and ``(u, names, report)`` returns;
        under ``lite`` the SAME jit as verify-off runs (the sandwich is
        entirely host-side) and ``(u, qs, w_valid, w_norm)`` returns (or
        None when the sandwich is unavailable for this config)."""
        fp = self._pad_input(f)
        spec = self.input_spec(local_batch)
        fp = jax.device_put(fp, NamedSharding(self.mesh, spec))
        names = rep = None
        if lite:
            ent = self._lite_pair(fp.shape, local_batch)
            if ent is None:
                return None
            qs, wv, wn = ent
            out = self.jit_for(local_batch)(fp, self.green_device())
        elif abft:
            fn, names = self.abft_jit_for(local_batch)
            out, rep = fn(fp, self.green_device())
        else:
            out = self.jit_for(local_batch)(fp, self.green_device())
        from repro.core.engine import crop_doubling
        d0, d1, d2 = self.plan.order
        off = out.ndim - 3
        out = _crop_dim(out, d1 + off, self._U[d1])
        out = _crop_dim(out, d2 + off, self._U[d2])
        out = crop_doubling(out, self.plan.dirs)
        if lite:
            return (out,) + ent
        return (out, names, rep) if abft else out

    @staticmethod
    def _lite_contract(out, qs):
        """Host side of ``<r, u>`` for the rank-1 probe: contract every
        addressable shard of the (cropped, sharded) output against the
        factor slices its global index selects, and accumulate into the
        leading (batch) dims.  Zero-copy on a host-device mesh; shards
        are deduped by index in case a mesh axis replicates them."""
        off = out.ndim - 3
        acc = np.zeros(out.shape[:off], np.float64)
        seen = set()
        for shard in out.addressable_shards:
            idx = shard.index
            key = tuple((sl.start, sl.stop) for sl in idx)
            if key in seen:
                continue
            seen.add(key)
            t = np.asarray(shard.data)
            for ax in (2, 1, 0):             # minor-most first
                t = np.tensordot(t, qs[ax][idx[off + ax]],
                                 axes=([t.ndim - 1], [0]))
            acc[idx[:off]] += t
        return acc

    def solve(self, f, verify=None):
        """f: global field, optionally with leading batch dims.

        Accepted ranks: ``(*grid)``; ``(B, *grid)`` (in-block multi-RHS
        batch, or the pod-sharded batch when ``batch_axis`` is set);
        ``(B_pod, B, *grid)`` (both).

        ``verify`` (default: the constructor's setting) opts into post-solve
        health checks ("nan" | "residual" | "abft"); any failure --
        injected fault, comm error, non-finite output, surviving checksum
        mismatch -- walks the degradation ladder (engine, comm strategy,
        relayout schedule, doubling) before raising a
        :class:`repro.runtime.SolveError` with stage provenance.  Under
        ``"abft"`` every transform stage and topology switch is checksum-
        sandwiched (DESIGN.md #13): transient flips are repaired in place
        by the inline selective recompute, repairs are recorded in
        ``stats["integrity"]``, and wire-attributed corruption retries as
        a transient before degrading.
        """
        from repro.runtime import abft as _abft
        from repro.runtime import faults, health, resilience
        f_host = f if (isinstance(f, np.ndarray)
                       and f.dtype == np.dtype(self.dtype)) else None
        f = jnp.asarray(f, dtype=self.dtype)
        base = 3 + (1 if self.batch_axis is not None else 0)
        assert f.ndim in (base, base + 1), (f.shape, base)
        local_batch = f.ndim == base + 1
        verify = self.verify if verify is None else verify

        def checked():
            out, names, rep = self._dispatch(f, local_batch, abft=True)
            _abft.verify_report(
                list(names), np.asarray(rep), tol=self._abft_tol(),
                stats=self.stats, describe="dist.solve")
            return out

        def attempt():
            faults.fail_point("dist.dispatch")
            if verify == "abft-stages":
                return checked()
            if verify == "abft":
                res = self._dispatch(f, local_batch, lite=True)
                if res is None:       # sandwich unavailable: checked mode
                    return checked()
                out, qs, wv, wn = res
                # on a host-platform mesh the "device" threads share the
                # machine's cores with this thread, so overlapping the host
                # dots with the async solve just causes cache/CPU
                # contention -- let the solve finish, then run both dots on
                # an uncontended machine (measured faster than overlap)
                jax.block_until_ready(out)
                # the <w,f> side: one BLAS dot against the raw user field
                # (the caller's numpy buffer when dtypes match: no device
                # round trip, no conversion pass)
                fh = f_host if f_host is not None else np.asarray(f)
                fw = fh.reshape(fh.shape[:-3] + (-1,))
                wf = wv.reshape(wv.shape[:-3] + (-1,))
                if fw.ndim == 1:
                    b = np.float64(np.dot(wf, fw))
                else:
                    b = np.einsum("...i,...i->...", wf, fw,
                                  dtype=np.float64)
                # the <r,u> side: per-shard chained BLAS contractions
                # against the rank-1 factors, on zero-copy host views of
                # each device buffer -- skips the (slow) full-array gather
                a = self._lite_contract(out, qs)
                a = a.reshape(np.shape(b))
                tol = self._abft_tol() * _abft.LITE_HEADROOM
                m = _abft.lite_mismatch_ab(a, b, np.zeros_like(wn))
                if m > tol:
                    # near-cancelling dots: only now pay for the noise
                    # floor ||w||*||f||/sqrt(N) before calling it a trip
                    fnorm = np.sqrt(np.einsum("...i,...i->...", fw, fw,
                                              dtype=np.float64))
                    floor = wn * fnorm / np.sqrt(wf.shape[-1])
                    m = _abft.lite_mismatch_ab(a, b, floor)
                if m <= tol:
                    return out
                # sandwich tripped: localize via the checked pipeline
                # (inline selective repair; persistent corruption raises
                # IntegrityError out of verify_report into the ladder)
                self.stats["verify_failures"] += 1
                self.stats.setdefault("integrity", []).append({
                    "stage": "solve.linearity", "kind": "linearity",
                    "mismatch": float(m), "tol": float(tol),
                    "action": "localize", "describe": "dist.solve"})
                return checked()
            out = self._dispatch(f, local_batch)
            if verify:
                locate = None
                if not self._ctor["lazy_green"]:
                    locate = lambda: health.locate_nonfinite_stage(
                        self.plan, self.schedule, f, self._green_raw)
                health.check_solution(out, f, self.plan, mode=verify,
                                      rtol=self.verify_rtol,
                                      stats=self.stats, locate=locate)
            return out

        out = resilience.run_with_ladder(
            attempt, config=self._cfg, reconfigure=self._configure,
            stats=self.stats, describe="dist.solve")
        self.stats["solves"] += 1
        return out

    # -- elastic recovery ----------------------------------------------------

    def rebuild(self, mesh, *, axes=None, comm=None):
        """Re-plan on a (possibly shrunken) surviving mesh.

        Returns a NEW solver for ``mesh``: the full construction identity is
        replayed (so pencil splits, padding, specs and jits all match the
        new device topology) while the expensive plan-time state is reused
        -- the raw transformed Green's function is handed over (never
        reassembled) and a comm ``"auto"`` request re-resolves through the
        persisted autotune JSON cache keyed by the new mesh.  Ladder state
        carries over: the current (possibly degraded) engine/relayout/
        doubling config seeds the new solver, and stale ``get_solver``
        entries for the OLD mesh are evicted so no caller can obtain a
        solver bound to dead devices.
        """
        from repro.core.solver import evict_solver_entries
        evict_solver_entries(self.mesh)
        c = self._ctor
        new = DistributedPoissonSolver(
            c["shape"], c["L"], c["bcs"], c["layout"], c["green_kind"],
            mesh=mesh, axes=tuple(axes) if axes is not None else self.axes,
            comm=comm if comm is not None else c["comm_req"],
            batch_axis=self.batch_axis, eps_factor=c["eps_factor"],
            dtype=self.dtype, lazy_green=c["lazy_green"],
            engine=(c["engine_obj"]
                    if c["engine_obj"].name == self._cfg["engine"]
                    else self._cfg["engine"]),
            doubling=self._cfg["doubling"],
            relayout=self._cfg["relayout"],
            order_policy=c["order_policy"],
            autotune_candidates=c["autotune_candidates"],
            autotune_cache=c["autotune_cache"],
            autotune_batch=c["autotune_batch"],
            autotune_budget=c["autotune_budget"],
            autotune_search=c.get("autotune_search", "guided"),
            verify=self.verify, verify_rtol=self.verify_rtol,
            abft_rtol=self.abft_rtol, _green_cache=self._green_raw)
        new.stats["degradations"] = list(self.stats["degradations"])
        return new

    def lower(self, batch=None, dtype=None, *, local_batch: bool = False):
        """Lower the jitted distributed solve with ShapeDtypeStructs (dry-run).

        ``batch`` sizes the leading batch dims: an int for the single one
        in play (the pod-sharded dim when ``batch_axis`` is set, else the
        in-block multi-RHS dim under ``local_batch=True``), or a
        ``(pod, local)`` pair when both are present.  Missing leading dims
        default to 1 so the lowered rank always matches the input spec.
        """
        dtype = dtype or self.dtype
        defaults = []           # leading dims in order: pod-sharded, local
        if self.batch_axis is not None:
            defaults.append(int(self.mesh.shape[self.batch_axis]))
        if local_batch:
            defaults.append(1)
        n_lead = len(defaults)
        lead = () if batch is None else (
            tuple(batch) if isinstance(batch, (tuple, list)) else (batch,))
        if len(lead) < n_lead:
            lead = tuple(defaults[:n_lead - len(lead)]) + lead
        assert len(lead) == n_lead, (batch, self.batch_axis, local_batch)
        shp = lead + self.padded_input_shape()
        spec = self.input_spec(local_batch)
        f = jax.ShapeDtypeStruct(shp, dtype,
                                 sharding=NamedSharding(self.mesh, spec))
        g = jax.ShapeDtypeStruct(self._green_np.shape, self._green_np.dtype,
                                 sharding=NamedSharding(self.mesh, self.g_spec))
        return self.jit_for(local_batch).lower(f, g)
