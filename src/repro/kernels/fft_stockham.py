"""Pallas TPU kernel: batched radix-4/2 Stockham complex FFT (last axis).

The 1-D FFT is the compute hot spot the paper delegates to fftw; on TPU we
keep a (batch_tile, N) block resident in VMEM and run all the Stockham
stages in-register -- the autosort variant needs no bit-reversal pass, so
every stage is a pure vectorized butterfly + twiddle multiply (VPU-shaped:
the N axis stays the 128-lane minor dimension).

Stages are RADIX-4 whenever the remaining sub-transform length divides by 4
(two radix-2 passes algebraically fused: half the stage count, half the
twiddle loads and pack shuffles on power-of-two lengths) with a single
radix-2 step absorbing the odd log2 factor.  ``max_radix=2`` forces the
pure radix-2 pipeline (the A/B baseline ``BENCH_kernels.json`` records).

Fusable epilogues run in the FINAL stage's registers, saving one full HBM
round trip each (flups' shuffle/pack folded into the transform itself):

* ``fft_stockham_twiddle`` -- the r2r post-twiddle
  ``y = a * re[start:start+k] + b * im[start:start+k]`` (the standalone
  ``twiddle_pack`` kernel's job) emitting only the k retained real bins;
* ``fft_stockham_scale``  -- the spectral Green multiply (the standalone
  ``spectral_scale`` kernel's job) scaling the ``[start, start+k)`` bins by
  a per-(row, bin) real plane, shared across any leading batch.

Complex data is (re, im) f32 pairs.  Twiddles are computed at trace time as
constants folded into the kernel (N is static).  VMEM budget: a
(8, 4096) block is 8 * 4096 * 2 * 4B * ~3 live buffers ~= 0.8 MB.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stages(n):
    k = int(np.log2(n))
    assert 2 ** k == n, f"stockham kernel needs power-of-two N, got {n}"
    return k


def stage_count(n: int, max_radix: int = 4, n_in=None) -> int:
    """Butterfly passes the kernel will run for a length-``n`` transform
    (the BENCH_kernels.json bookkeeping; radix-4 halves it on pow2 N)."""
    k = _stages(n)
    if n_in is not None and n_in < n:
        k -= 1                      # the degenerate pruned first stage
    if max_radix < 4:
        return k + (1 if n_in is not None and n_in < n else 0)
    return k // 2 + k % 2 + (1 if n_in is not None and n_in < n else 0)


def _fft_body(xr, xi, *, n, inverse, n_in=None, max_radix=4):
    """All Stockham stages on a (batch_tile, n) register block.

    ``n_in`` < n activates the PRUNED first stage (Hockney zero tail): the
    inputs hold only the n_in = n//2 nonzero samples, and the first DIF
    stage -- whose upper-half operand is identically zero -- degenerates to
    a copy + twiddle modulation (no adds, half the stage-1 VMEM reads).
    """
    br = xr.shape[0]
    sign = 2.0 * np.pi / n if inverse else -2.0 * np.pi / n
    m, l = n, 1
    if n_in is not None and n_in < n:
        assert n == 2 * n_in and not inverse
        half = n // 2
        ang = jnp.arange(half, dtype=xr.dtype) * xr.dtype.type(sign)
        wr = jnp.cos(ang)
        wi = jnp.sin(ang)
        # x1 == 0: e = x0, d = x0 * w  (the skipped butterflies)
        orr = xr * wr - xi * wi
        oii = xr * wi + xi * wr
        xr = jnp.concatenate([xr[..., None], orr[..., None]],
                             axis=2).reshape(br, half, 2).reshape(br, n)
        xi = jnp.concatenate([xi[..., None], oii[..., None]],
                             axis=2).reshape(br, half, 2).reshape(br, n)
        m, l = half, 2
    while m > 1:
        if m % 4 == 0 and max_radix >= 4:
            # radix-4 DIF stage == two fused radix-2 stages: quarters
            # (A, B, C, D) of each length-m sub-transform combine as
            #   y0 = (A+C) + (B+D)
            #   y1 = ((A-C) -+ i(B-D)) W^j      y2 = ((A+C) - (B+D)) W^2j
            #   y3 = ((A-C) +- i(B-D)) W^3j
            # packed [y0 y1 y2 y3] into the l-axis (the Stockham autosort
            # order two radix-2 passes would have produced).
            q = m // 4
            xr4 = xr.reshape(br, m, l)
            xi4 = xi.reshape(br, m, l)
            ar, brr, cr, dr = (xr4[:, i * q:(i + 1) * q, :] for i in range(4))
            ai, bii, ci, di = (xi4[:, i * q:(i + 1) * q, :] for i in range(4))
            t0r, t0i = ar + cr, ai + ci
            t1r, t1i = ar - cr, ai - ci
            t2r, t2i = brr + dr, bii + di
            t3r, t3i = brr - dr, bii - di
            if inverse:     # +i * t3
                u3r, u3i = -t3i, t3r
            else:           # -i * t3
                u3r, u3i = t3i, -t3r
            ang = (jnp.arange(q, dtype=xr.dtype) *
                   xr.dtype.type(sign * (n // m)))
            w1r = jnp.cos(ang)[None, :, None]
            w1i = jnp.sin(ang)[None, :, None]
            w2r = jnp.cos(2.0 * ang)[None, :, None]
            w2i = jnp.sin(2.0 * ang)[None, :, None]
            w3r = jnp.cos(3.0 * ang)[None, :, None]
            w3i = jnp.sin(3.0 * ang)[None, :, None]
            y0r, y0i = t0r + t2r, t0i + t2i
            e1r, e1i = t1r + u3r, t1i + u3i
            y1r = e1r * w1r - e1i * w1i
            y1i = e1r * w1i + e1i * w1r
            e2r, e2i = t0r - t2r, t0i - t2i
            y2r = e2r * w2r - e2i * w2i
            y2i = e2r * w2i + e2i * w2r
            e3r, e3i = t1r - u3r, t1i - u3i
            y3r = e3r * w3r - e3i * w3i
            y3i = e3r * w3i + e3i * w3r
            xr = jnp.concatenate(
                [y0r[..., None, :], y1r[..., None, :],
                 y2r[..., None, :], y3r[..., None, :]],
                axis=2).reshape(br, q, 4 * l).reshape(br, n)
            xi = jnp.concatenate(
                [y0i[..., None, :], y1i[..., None, :],
                 y2i[..., None, :], y3i[..., None, :]],
                axis=2).reshape(br, q, 4 * l).reshape(br, n)
            m, l = q, 4 * l
            continue
        half = m // 2
        # radix-2 step (the odd log2 factor, or the whole pipeline under
        # max_radix=2); view as (batch, m, l)
        xr3 = xr.reshape(br, m, l)
        xi3 = xi.reshape(br, m, l)
        x0r, x1r = xr3[:, :half, :], xr3[:, half:, :]
        x0i, x1i = xi3[:, :half, :], xi3[:, half:, :]
        # twiddles computed in-kernel (iota -> cos/sin on the VPU); n, m
        # are static so sign*(n//m) folds to an immediate
        ang = (jnp.arange(half, dtype=xr.dtype) *
               xr.dtype.type(sign * (n // m)))
        wr = jnp.cos(ang)[None, :, None]
        wi = jnp.sin(ang)[None, :, None]
        er, ei = x0r + x1r, x0i + x1i
        dr, di = x0r - x1r, x0i - x1i
        orr = dr * wr - di * wi
        oii = dr * wi + di * wr
        xr = jnp.concatenate([er[..., None, :], orr[..., None, :]],
                             axis=2).reshape(br, half, 2 * l).reshape(br, n)
        xi = jnp.concatenate([ei[..., None, :], oii[..., None, :]],
                             axis=2).reshape(br, half, 2 * l).reshape(br, n)
        m, l = half, 2 * l
    if inverse:
        xr = xr / n
        xi = xi / n
    return xr, xi


def _kernel(re_ref, im_ref, out_re_ref, out_im_ref, *, n, inverse,
            n_in=None, max_radix=4):
    """One (batch_tile, n) FFT block, full complex spectrum out."""
    xr, xi = _fft_body(re_ref[...], im_ref[...], n=n, inverse=inverse,
                       n_in=n_in, max_radix=max_radix)
    out_re_ref[...] = xr
    out_im_ref[...] = xi


def _kernel_twiddle(re_ref, im_ref, a_ref, b_ref, out_ref, *, n, n_in,
                    start, k, max_radix):
    """FFT + r2r post-twiddle epilogue: the final stage's registers feed
    ``y = a * re + b * im`` over bins [start, start+k) directly -- no full
    spectrum ever reaches HBM."""
    xr, xi = _fft_body(re_ref[...], im_ref[...], n=n, inverse=False,
                       n_in=n_in, max_radix=max_radix)
    out_ref[...] = (a_ref[...] * xr[:, start:start + k] +
                    b_ref[...] * xi[:, start:start + k])


def _kernel_scale(re_ref, im_ref, g_ref, out_re_ref, out_im_ref, *, n,
                  n_in, start, k, max_radix):
    """FFT + spectral-scale epilogue (3-D refs, leading batch of size 1 per
    grid step): the Green multiply runs on the final stage's registers and
    only the scaled [start, start+k) bins are written."""
    xr, xi = _fft_body(re_ref[0], im_ref[0], n=n, inverse=False,
                       n_in=n_in, max_radix=max_radix)
    g = g_ref[...]
    out_re_ref[0] = xr[:, start:start + k] * g
    out_im_ref[0] = xi[:, start:start + k] * g


def _pruned(n, pad_to, inverse):
    """(n_fft, n_in) of the optionally zero-tail-pruned forward shape."""
    if pad_to is None:
        _stages(n)
        return n, None
    assert pad_to == 2 * n, (pad_to, n)
    assert not inverse, "pruned zero-tail input is a forward-only shape"
    _stages(pad_to)
    return pad_to, n


def fft_stockham(re, im, batch_block=8, inverse=False, interpret=True,
                 pad_to=None, max_radix=4):
    """re/im: (batch, N) f32 -> (re, im) of the complex FFT along axis -1.

    ``pad_to = 2 * N`` computes the length-``pad_to`` FFT of the signal
    zero-extended to double length (the Hockney doubling shape) WITHOUT
    materializing the zeros: the kernel reads the (batch, N) block and
    runs a degenerate first stage (see ``_fft_body``), emitting (batch,
    pad_to) spectra.  Forward only.
    """
    b, n = re.shape
    n_out, n_in = _pruned(n, pad_to, inverse)
    bb = min(batch_block, b)
    grid = (pl.cdiv(b, bb),)
    spec_in = pl.BlockSpec((bb, n), lambda i: (i, 0))
    spec_out = pl.BlockSpec((bb, n_out), lambda i: (i, 0))
    fn = pl.pallas_call(
        partial(_kernel, n=n_out, inverse=inverse, n_in=n_in,
                max_radix=max_radix),
        grid=grid,
        in_specs=[spec_in, spec_in],
        out_specs=[spec_out, spec_out],
        out_shape=[jax.ShapeDtypeStruct((b, n_out), re.dtype),
                   jax.ShapeDtypeStruct((b, n_out), im.dtype)],
        interpret=interpret,
    )
    return fn(re, im)


def fft_stockham_twiddle(re, im, a, b, start=0, batch_block=8,
                         interpret=True, pad_to=None, max_radix=4):
    """Forward FFT fused with the r2r post-twiddle epilogue.

    re/im: (batch, N); a/b: (k,) twiddle tables.  Returns the real
    (batch, k) array ``a * Re(F)[start:start+k] + b * Im(F)[start:start+k]``
    in ONE kernel -- the ``twiddle_pack`` pass runs in the FFT's final-stage
    registers instead of as its own HBM round trip.
    """
    bsz, n = re.shape
    n_out, n_in = _pruned(n, pad_to, False)
    k = a.shape[-1]
    assert b.shape[-1] == k and start + k <= n_out, (a.shape, start, n_out)
    bb = min(batch_block, bsz)
    grid = (pl.cdiv(bsz, bb),)
    spec_in = pl.BlockSpec((bb, n), lambda i: (i, 0))
    vec = pl.BlockSpec((1, k), lambda i: (0, 0))
    fn = pl.pallas_call(
        partial(_kernel_twiddle, n=n_out, n_in=n_in, start=start, k=k,
                max_radix=max_radix),
        grid=grid,
        in_specs=[spec_in, spec_in, vec, vec],
        out_specs=pl.BlockSpec((bb, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, k), re.dtype),
        interpret=interpret,
    )
    return fn(re, im, a.reshape(1, k), b.reshape(1, k))


def fft_stockham_scale(re, im, g, start=0, batch_block=8, interpret=True,
                       pad_to=None, max_radix=4):
    """Forward FFT fused with the spectral Green-multiply epilogue.

    re/im: (rows, N); g: (grows, k) with rows % grows == 0 (leading
    multi-RHS batch shares one Green plane).  Returns the complex pair
    ``(Re(F) * g, Im(F) * g)`` over bins [start, start+k), shape (rows, k),
    in ONE kernel -- the ``spectral_scale`` pass runs in the FFT's
    final-stage registers.
    """
    rows, n = re.shape
    n_out, n_in = _pruned(n, pad_to, False)
    grows, k = g.shape
    assert rows % grows == 0, (rows, grows)
    assert start + k <= n_out, (start, k, n_out)
    nb = rows // grows
    re3 = re.reshape(nb, grows, n)
    im3 = im.reshape(nb, grows, n)
    bb = min(batch_block, grows)
    grid = (nb, pl.cdiv(grows, bb))
    spec_in = pl.BlockSpec((1, bb, n), lambda b_, i: (b_, i, 0))
    spec_out = pl.BlockSpec((1, bb, k), lambda b_, i: (b_, i, 0))
    gspec = pl.BlockSpec((bb, k), lambda b_, i: (i, 0))
    fn = pl.pallas_call(
        partial(_kernel_scale, n=n_out, n_in=n_in, start=start, k=k,
                max_radix=max_radix),
        grid=grid,
        in_specs=[spec_in, spec_in, gspec],
        out_specs=[spec_out, spec_out],
        out_shape=[jax.ShapeDtypeStruct((nb, grows, k), re.dtype),
                   jax.ShapeDtypeStruct((nb, grows, k), im.dtype)],
        interpret=interpret,
    )
    orr, oi = fn(re3, im3, g)
    return orr.reshape(rows, k), oi.reshape(rows, k)
