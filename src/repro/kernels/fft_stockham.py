"""Pallas TPU kernel: batched radix-2 Stockham complex FFT (last axis).

The 1-D FFT is the compute hot spot the paper delegates to fftw; on TPU we
keep a (batch_tile, N) block resident in VMEM and run all log2(N) Stockham
stages in-register -- the autosort variant needs no bit-reversal pass, so
every stage is a pure vectorized butterfly + twiddle multiply (VPU-shaped:
the N axis stays the 128-lane minor dimension).

Complex data is (re, im) f32 pairs.  Twiddles are computed at trace time as
constants folded into the kernel (N is static).  VMEM budget: a
(8, 4096) block is 8 * 4096 * 2 * 4B * ~3 live buffers ~= 0.8 MB.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stages(n):
    k = int(np.log2(n))
    assert 2 ** k == n, f"radix-2 kernel needs power-of-two N, got {n}"
    return k


def _kernel(re_ref, im_ref, out_re_ref, out_im_ref, *, n, inverse,
            n_in=None):
    """One (batch_tile, n) FFT block.  ``n_in`` < n activates the PRUNED
    first stage (Hockney zero tail): the refs hold only the n_in = n//2
    nonzero inputs, and the first DIF stage -- whose upper-half operand is
    identically zero -- degenerates to a copy + twiddle modulation (no adds,
    half the stage-1 VMEM reads)."""
    br = re_ref.shape[0]
    xr = re_ref[...]
    xi = im_ref[...]
    sign = 2.0 * np.pi / n if inverse else -2.0 * np.pi / n
    m, l = n, 1
    if n_in is not None and n_in < n:
        assert n == 2 * n_in and not inverse
        half = n // 2
        ang = jnp.arange(half, dtype=xr.dtype) * xr.dtype.type(sign)
        wr = jnp.cos(ang)
        wi = jnp.sin(ang)
        # x1 == 0: e = x0, d = x0 * w  (the skipped butterflies)
        orr = xr * wr - xi * wi
        oii = xr * wi + xi * wr
        xr = jnp.concatenate([xr[..., None], orr[..., None]],
                             axis=2).reshape(br, half, 2).reshape(br, n)
        xi = jnp.concatenate([xi[..., None], oii[..., None]],
                             axis=2).reshape(br, half, 2).reshape(br, n)
        m, l = half, 2
    while m > 1:
        half = m // 2
        # view as (batch, m, l)
        xr3 = xr.reshape(br, m, l)
        xi3 = xi.reshape(br, m, l)
        x0r, x1r = xr3[:, :half, :], xr3[:, half:, :]
        x0i, x1i = xi3[:, :half, :], xi3[:, half:, :]
        # twiddles computed in-kernel (iota -> cos/sin on the VPU); n, m
        # are static so sign*(n//m) folds to an immediate
        ang = (jnp.arange(half, dtype=xr.dtype) *
               xr.dtype.type(sign * (n // m)))
        wr = jnp.cos(ang)[None, :, None]
        wi = jnp.sin(ang)[None, :, None]
        er, ei = x0r + x1r, x0i + x1i
        dr, di = x0r - x1r, x0i - x1i
        orr = dr * wr - di * wi
        oii = dr * wi + di * wr
        xr = jnp.concatenate([er[..., None, :], orr[..., None, :]],
                             axis=2).reshape(br, half, 2 * l).reshape(br, n)
        xi = jnp.concatenate([ei[..., None, :], oii[..., None, :]],
                             axis=2).reshape(br, half, 2 * l).reshape(br, n)
        m, l = half, 2 * l
    if inverse:
        xr = xr / n
        xi = xi / n
    out_re_ref[...] = xr
    out_im_ref[...] = xi


def fft_stockham(re, im, batch_block=8, inverse=False, interpret=True,
                 pad_to=None):
    """re/im: (batch, N) f32 -> (re, im) of the complex FFT along axis -1.

    ``pad_to = 2 * N`` computes the length-``pad_to`` FFT of the signal
    zero-extended to double length (the Hockney doubling shape) WITHOUT
    materializing the zeros: the kernel reads the (batch, N) block and
    runs a degenerate first stage (see ``_kernel``), emitting (batch,
    pad_to) spectra.  Forward only.
    """
    b, n = re.shape
    if pad_to is None:
        _stages(n)
        n_out, n_in = n, None
    else:
        assert pad_to == 2 * n, (pad_to, n)
        assert not inverse, "pruned zero-tail input is a forward-only shape"
        _stages(pad_to)
        n_out, n_in = pad_to, n
    bb = min(batch_block, b)
    grid = (pl.cdiv(b, bb),)
    spec_in = pl.BlockSpec((bb, n), lambda i: (i, 0))
    spec_out = pl.BlockSpec((bb, n_out), lambda i: (i, 0))
    fn = pl.pallas_call(
        partial(_kernel, n=n_out, inverse=inverse, n_in=n_in),
        grid=grid,
        in_specs=[spec_in, spec_in],
        out_specs=[spec_out, spec_out],
        out_shape=[jax.ShapeDtypeStruct((b, n_out), re.dtype),
                   jax.ShapeDtypeStruct((b, n_out), im.dtype)],
        interpret=interpret,
    )
    return fn(re, im)
