"""Jitted public wrappers for the Pallas kernels (+ dtype plumbing).

``interpret=True`` everywhere in this environment: the kernel bodies
execute on CPU for validation; on a real TPU runtime the same calls lower
to Mosaic with the declared BlockSpecs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .fft_stockham import fft_stockham
from .spectral_scale import spectral_scale
from .twiddle_pack import twiddle_pack


@partial(jax.jit, static_argnames=("scale", "interpret"))
def green_multiply(fhat, green, scale: float, interpret: bool = True):
    """Complex (or real) spectral field times real Green + norm factor."""
    shp = fhat.shape
    rows = 1
    for s in shp[:-1]:
        rows *= s
    lanes = shp[-1]
    g2 = green.reshape(rows, lanes).astype(jnp.float32)
    if jnp.iscomplexobj(fhat):
        re = fhat.real.reshape(rows, lanes).astype(jnp.float32)
        im = fhat.imag.reshape(rows, lanes).astype(jnp.float32)
        orr, oi = spectral_scale(re, im, g2, scale, interpret=interpret)
        return (orr + 1j * oi).reshape(shp).astype(fhat.dtype)
    re = fhat.reshape(rows, lanes).astype(jnp.float32)
    orr, _ = spectral_scale(re, re, g2, scale, interpret=interpret)
    return orr.reshape(shp).astype(fhat.dtype)


@partial(jax.jit, static_argnames=("interpret",))
def dct2_post_twiddle(fhat_half, interpret: bool = True):
    """DCT-II from the rfft of the symmetric extension (transforms.dct2
    inner step): y_k = cos_k * re_k + sin_k * im_k over the first M modes."""
    import numpy as np
    rows, m = fhat_half.shape
    k = jnp.arange(m)
    cos = jnp.cos(np.pi * k / (2.0 * m)).astype(jnp.float32)
    sin = jnp.sin(np.pi * k / (2.0 * m)).astype(jnp.float32)
    re = fhat_half.real.astype(jnp.float32)
    im = fhat_half.imag.astype(jnp.float32)
    # dct2 = Re(e^{-i pi k / 2M} F_k) = cos*re + sin*im
    return twiddle_pack(re, im, cos, sin, interpret=interpret)


@partial(jax.jit, static_argnames=("inverse", "interpret"))
def fft1d(x, inverse: bool = False, interpret: bool = True):
    """Batched complex FFT via the Stockham kernel. x: (..., N) complex."""
    shp = x.shape
    rows = 1
    for s in shp[:-1]:
        rows *= s
    re = x.real.reshape(rows, shp[-1]).astype(jnp.float32)
    im = x.imag.reshape(rows, shp[-1]).astype(jnp.float32)
    orr, oi = fft_stockham(re, im, inverse=inverse, interpret=interpret)
    return (orr + 1j * oi).reshape(shp).astype(
        jnp.complex64 if x.dtype != jnp.complex128 else jnp.complex128)
