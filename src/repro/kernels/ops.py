"""Jitted public wrappers for the Pallas kernels (+ dtype plumbing).

``interpret=True`` everywhere in this environment: the kernel bodies
execute on CPU for validation; on a real TPU runtime the same calls lower
to Mosaic with the declared BlockSpecs.

All wrappers preserve the input dtype (f64 runs fine in interpret mode;
on a real TPU the solver feeds f32), so ``engine="pallas"`` matches
``engine="xla"`` to roundoff instead of truncating to f32.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .fft_stockham import (fft_stockham, fft_stockham_scale,
                           fft_stockham_twiddle)
from .spectral_scale import spectral_scale
from .twiddle_pack import twiddle_pack


def _rows(shape):
    r = 1
    for s in shape[:-1]:
        r *= s
    return r


def _cdt(real_dtype):
    return jnp.complex128 if real_dtype == jnp.float64 else jnp.complex64


@jax.jit
def green_checksum(fhat, green):
    """Reference side of the ABFT Green-multiply invariant (DESIGN.md #13).

    The spectral pointwise pass is linear in ``fhat``, so its output must
    reduce to ``sum(fhat * green)``; this computes that reference as ONE
    fused multiply-reduce (never materializing the product block), which
    is what keeps the ``verify="abft"`` overhead of checking the solve's
    only O(N^3) pointwise pass negligible.
    """
    g = green if jnp.iscomplexobj(fhat) else green.astype(fhat.dtype)
    return jnp.sum(fhat * g)


@partial(jax.jit, static_argnames=("scale", "interpret"))
def green_multiply(fhat, green, scale: float = 1.0, interpret: bool = True):
    """Complex (or real) spectral field times real Green + norm factor.

    The only O(N^3) pointwise pass of the solve: one fused kernel instead
    of separate Green / normalization multiplies.  ``fhat`` may carry
    leading batch axes over a shared ``green`` (multi-RHS solves): the
    kernel then grids over the flattened batch instead of broadcasting the
    Green plane into a batched HBM copy.
    """
    shp = fhat.shape
    bnd = fhat.ndim - green.ndim
    grows, lanes = _rows(green.shape), green.shape[-1]
    batch = 1
    for s in shp[:bnd]:
        batch *= s
    kshape = (batch, grows, lanes) if bnd else (grows, lanes)
    if jnp.iscomplexobj(fhat):
        rdt = jnp.float64 if fhat.dtype == jnp.complex128 else jnp.float32
        g2 = green.reshape(grows, lanes).astype(rdt)
        re = fhat.real.reshape(kshape).astype(rdt)
        im = fhat.imag.reshape(kshape).astype(rdt)
        orr, oi = spectral_scale(re, im, g2, scale, interpret=interpret)
        return (orr + 1j * oi).reshape(shp).astype(fhat.dtype)
    g2 = green.reshape(grows, lanes).astype(fhat.dtype)
    re = fhat.reshape(kshape)
    orr, _ = spectral_scale(re, re, g2, scale, interpret=interpret)
    return orr.reshape(shp).astype(fhat.dtype)


@partial(jax.jit, static_argnames=("interpret",))
def post_twiddle(re, im, a, b, interpret: bool = True):
    """Generic r2r post-twiddle ``y = a * re + b * im`` over the last axis.

    ``re``/``im``: (..., k) real planes of the rfft half spectrum;
    ``a``/``b``: (k,) twiddle tables (any float dtype; cast to ``re``).
    """
    shp = re.shape
    rows, k = _rows(shp), shp[-1]
    av = jnp.asarray(a, dtype=re.dtype)
    bv = jnp.asarray(b, dtype=re.dtype)
    y = twiddle_pack(re.reshape(rows, k), im.reshape(rows, k).astype(re.dtype),
                     av, bv, interpret=interpret)
    return y.reshape(shp)


@partial(jax.jit, static_argnames=("interpret",))
def dct2_post_twiddle(fhat_half, interpret: bool = True):
    """DCT-II from the rfft of the symmetric extension (transforms.dct2
    inner step): y_k = cos_k * re_k + sin_k * im_k over the first M modes."""
    import numpy as np
    m = fhat_half.shape[-1]
    k = np.arange(m)
    return post_twiddle(fhat_half.real, fhat_half.imag,
                        np.cos(np.pi * k / (2.0 * m)),
                        np.sin(np.pi * k / (2.0 * m)), interpret=interpret)


@partial(jax.jit, static_argnames=("start", "interpret", "pad_to",
                                   "max_radix"))
def rfft_twiddle(x, a, b, start: int = 0, interpret: bool = True,
                 pad_to: int | None = None, max_radix: int = 4):
    """Fused rfft + r2r post-twiddle: ``a * Re(F)[start:start+k] +
    b * Im(F)[start:start+k]`` of the real (..., N) array ``x`` in ONE
    Pallas kernel (the ``twiddle_pack`` pass runs in the FFT's final-stage
    registers -- one HBM round trip instead of three).  ``pad_to = 2N``
    composes with the pruned Hockney zero tail."""
    shp = x.shape
    n = shp[-1]
    rows = _rows(shp)
    re = x.reshape(rows, n)
    im = jnp.zeros_like(re)
    av = jnp.asarray(a, dtype=x.dtype)
    bv = jnp.asarray(b, dtype=x.dtype)
    y = fft_stockham_twiddle(re, im, av, bv, start=start,
                             interpret=interpret, pad_to=pad_to,
                             max_radix=max_radix)
    return y.reshape(shp[:-1] + (av.shape[-1],))


def _fft_green(x, green2d, half: bool, interpret: bool, pad_to,
               max_radix: int = 4):
    """Shared body of the fused forward-FFT x Green epilogues."""
    shp = x.shape
    n = shp[-1]
    rows = _rows(shp)
    if jnp.iscomplexobj(x):
        rdt = jnp.float64 if x.dtype == jnp.complex128 else jnp.float32
        re = x.real.reshape(rows, n).astype(rdt)
        im = x.imag.reshape(rows, n).astype(rdt)
    else:
        rdt = x.dtype
        re = x.reshape(rows, n)
        im = jnp.zeros_like(re)
    n_fft = pad_to if pad_to is not None else n
    k = n_fft // 2 + 1 if half else n_fft
    g2 = green2d.reshape(-1, k).astype(rdt)
    orr, oi = fft_stockham_scale(re, im, g2, start=0, interpret=interpret,
                                 pad_to=pad_to, max_radix=max_radix)
    return (orr + 1j * oi).reshape(shp[:-1] + (k,)).astype(_cdt(rdt))


@partial(jax.jit, static_argnames=("interpret", "pad_to", "max_radix"))
def fft1d_green(x, green, interpret: bool = True, pad_to: int | None = None,
                max_radix: int = 4):
    """Fused forward complex FFT x Green multiply: ``FFT(x) * green`` with
    ``green`` real of shape (..., n_fft) broadcast over any leading batch
    of ``x`` -- the last forward direction's ``spectral_scale`` pass runs
    in the FFT's final-stage registers."""
    return _fft_green(x, green, half=False, interpret=interpret,
                      pad_to=pad_to, max_radix=max_radix)


@partial(jax.jit, static_argnames=("interpret", "pad_to", "max_radix"))
def rfft_green(x, green, interpret: bool = True, pad_to: int | None = None,
               max_radix: int = 4):
    """Fused rfft x Green multiply on the half spectrum: ``rfft(x) * green``
    with ``green`` real of shape (..., n_fft//2+1); ``pad_to = 2N`` prunes
    the Hockney zero tail inside the same kernel."""
    return _fft_green(x, green, half=True, interpret=interpret,
                      pad_to=pad_to, max_radix=max_radix)


@partial(jax.jit, static_argnames=("inverse", "interpret", "pad_to",
                                   "max_radix"))
def fft1d(x, inverse: bool = False, interpret: bool = True,
          pad_to: int | None = None, max_radix: int = 4):
    """Batched complex FFT via the Stockham kernel. x: (..., N) complex.

    ``pad_to = 2N`` is the PRUNED Hockney-doubling entry point: the
    length-2N spectrum of the zero-tail-extended signal, computed without
    materializing the zeros (the kernel's degenerate first stage)."""
    shp = x.shape
    rows = _rows(shp)
    rdt = jnp.float64 if x.dtype == jnp.complex128 else jnp.float32
    re = x.real.reshape(rows, shp[-1]).astype(rdt)
    im = x.imag.reshape(rows, shp[-1]).astype(rdt)
    orr, oi = fft_stockham(re, im, inverse=inverse, interpret=interpret,
                           pad_to=pad_to, max_radix=max_radix)
    n_out = pad_to if pad_to is not None else shp[-1]
    return (orr + 1j * oi).reshape(shp[:-1] + (n_out,)).astype(_cdt(rdt))


@partial(jax.jit, static_argnames=("interpret", "pad_to", "max_radix"))
def rfft_pallas(x, interpret: bool = True, pad_to: int | None = None,
                max_radix: int = 4):
    """rfft of a real (..., N) array via the Stockham kernel: complex FFT
    with a zero imaginary plane, cropped to the half spectrum.  ``pad_to =
    2N`` prunes the Hockney zero tail (length-2N spectrum, N+1 bins kept,
    no materialized padding)."""
    shp = x.shape
    n = shp[-1]
    rows = _rows(shp)
    re = x.reshape(rows, n)
    im = jnp.zeros_like(re)
    orr, oi = fft_stockham(re, im, interpret=interpret, pad_to=pad_to,
                           max_radix=max_radix)
    half = (pad_to if pad_to is not None else n) // 2 + 1
    out = (orr[:, :half] + 1j * oi[:, :half]).astype(_cdt(x.dtype))
    return out.reshape(shp[:-1] + (half,))


@partial(jax.jit, static_argnames=("keep", "interpret", "max_radix"))
def ifft_pruned(y, keep: int, interpret: bool = True, max_radix: int = 4):
    """First ``keep`` samples of the length-2n inverse FFT of ``y`` via the
    parity split: x_j = (ifft_n(Y_even)_j + e^{i pi j / n} ifft_n(Y_odd)_j)
    / 2 for j < n -- two half-length Stockham inverses instead of one
    double-length inverse plus a crop (``keep <= n`` required)."""
    shp = y.shape
    n2 = shp[-1]
    n = n2 // 2
    assert keep <= n, (keep, n2)
    rows = _rows(shp)
    rdt = jnp.float64 if y.dtype == jnp.complex128 else jnp.float32
    y2 = y.reshape(rows, n2)
    halves = []
    for part in (y2[:, 0::2], y2[:, 1::2]):
        orr, oi = fft_stockham(part.real.astype(rdt), part.imag.astype(rdt),
                               inverse=True, interpret=interpret,
                               max_radix=max_radix)
        halves.append(orr + 1j * oi)
    j = jnp.arange(n, dtype=rdt)
    mod = jnp.exp(1j * jnp.pi * j / n).astype(_cdt(rdt))
    out = 0.5 * (halves[0] + mod[None, :] * halves[1])
    return out[:, :keep].reshape(shp[:-1] + (keep,)).astype(_cdt(rdt))


@partial(jax.jit, static_argnames=("n", "keep", "interpret", "max_radix"))
def irfft_pruned(y, n: int, keep: int, interpret: bool = True,
                 max_radix: int = 4):
    """First ``keep`` samples of the length-``n`` irfft of a hermitian half
    spectrum (..., n//2+1): hermitian extension + parity-split pruned
    inverse, real part."""
    shp = y.shape
    rows = _rows(shp)
    y2 = y.reshape(rows, shp[-1])
    tail = jnp.conj(y2[:, n - n // 2 - 1:0:-1])
    full = jnp.concatenate([y2, tail], axis=-1)
    out = ifft_pruned(full, keep, interpret=interpret,
                      max_radix=max_radix)
    rdt = jnp.float64 if y.dtype == jnp.complex128 else jnp.float32
    return out.real.reshape(shp[:-1] + (keep,)).astype(rdt)


@partial(jax.jit, static_argnames=("n", "interpret", "max_radix"))
def irfft_pallas(y, n: int, interpret: bool = True, max_radix: int = 4):
    """irfft of a hermitian half spectrum (..., N//2+1) -> real (..., N)."""
    shp = y.shape
    rows = _rows(shp)
    y2 = y.reshape(rows, shp[-1])
    # hermitian extension to the full length-n spectrum
    tail = jnp.conj(y2[:, n - n // 2 - 1:0:-1])
    full = jnp.concatenate([y2, tail], axis=-1)
    rdt = jnp.float64 if y.dtype == jnp.complex128 else jnp.float32
    orr, _ = fft_stockham(full.real.astype(rdt), full.imag.astype(rdt),
                          inverse=True, interpret=interpret,
                          max_radix=max_radix)
    return orr.reshape(shp[:-1] + (n,)).astype(rdt)
