"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def spectral_scale_ref(re, im, green, scale):
    """Fused Green-function multiply + normalization (the convolution)."""
    return re * green * scale, im * green * scale


def twiddle_dct2_ref(re, im, cos, sin):
    """DCT-II post-twiddle: y_k = cos_k * re_k + sin_k * im_k (rows, k)."""
    return cos * re + sin * im


def fft_ref(re, im):
    """Complex FFT over the last axis."""
    out = jnp.fft.fft(re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64),
                      axis=-1)
    return out.real.astype(re.dtype), out.imag.astype(im.dtype)


def stockham_fft_np(re, im):
    """Numpy Stockham radix-2 reference (mirrors the kernel algorithm)."""
    x = re.astype(np.complex128) + 1j * im.astype(np.complex128)
    b, n = x.shape
    m, l = n, 1
    X = x.reshape(b, m, l)
    while m > 1:
        half = m // 2
        x0, x1 = X[:, :half, :], X[:, half:, :]
        w = np.exp(-2j * np.pi * np.arange(half) / m)[None, :, None]
        even = x0 + x1
        odd = (x0 - x1) * w
        X = np.concatenate([even, odd], axis=2).reshape(b, half, 2 * l)
        m, l = half, 2 * l
    return X.reshape(b, n)
