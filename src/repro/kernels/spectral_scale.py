"""Pallas TPU kernel: fused Green-function multiply + normalization.

The spectral convolution u_hat = f_hat * G_hat * norm is the only O(N^3)
pointwise pass of the solve; fusing the complex scale with the
normalization halves its HBM traffic vs two separate elementwise ops.

Complex data is carried as separate (re, im) f32 planes (TPU-native: the
MXU/VPU have no complex type).  Blocks are (rows_tile, lane_tile) VMEM
tiles over a (rows, lanes) view, 8x128-aligned.

Batched multi-RHS solves add a leading grid dimension: ``re``/``im`` of
shape (B, rows, lanes) against ONE shared (rows, lanes) Green plane -- the
kernel grids over (B, row tiles, lane tiles) and the Green BlockSpec simply
ignores the batch index, so the kernel streams the Green tile from VMEM B
times instead of materializing a broadcast copy in HBM.

When the last forward direction is a power-of-two DFT this pass no longer
runs standalone: ``fft_stockham_scale`` executes the same multiply in that
FFT's final-stage registers (DESIGN.md #9).  This kernel remains the path
for every other plan shape and the backward-normalization-free contract's
reference.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 256)


def _kernel(re_ref, im_ref, g_ref, out_re_ref, out_im_ref, *, scale):
    g = g_ref[...] * scale
    out_re_ref[...] = re_ref[...] * g
    out_im_ref[...] = im_ref[...] * g


def _kernel_batched(re_ref, im_ref, g_ref, out_re_ref, out_im_ref, *, scale):
    g = g_ref[...] * scale
    out_re_ref[0] = re_ref[0] * g
    out_im_ref[0] = im_ref[0] * g


def spectral_scale(re, im, green, scale: float,
                   block=DEFAULT_BLOCK, interpret=True):
    """re/im: (rows, lanes) or (B, rows, lanes); green: (rows, lanes).

    Returns the scaled (re, im) pair with the input shape; the batched form
    shares one Green plane across the leading axis.
    """
    batched = re.ndim == 3
    rows, lanes = re.shape[-2:]
    br = min(block[0], rows)
    bl = min(block[1], lanes)
    gspec2d = pl.BlockSpec((br, bl), lambda *ij: ij[-2:])
    if batched:
        grid = (re.shape[0], pl.cdiv(rows, br), pl.cdiv(lanes, bl))
        spec = pl.BlockSpec((1, br, bl), lambda b, i, j: (b, i, j))
        body = _kernel_batched
    else:
        grid = (pl.cdiv(rows, br), pl.cdiv(lanes, bl))
        spec = gspec2d
        body = _kernel
    fn = pl.pallas_call(
        partial(body, scale=scale),
        grid=grid,
        in_specs=[spec, spec, gspec2d],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(re.shape, re.dtype),
                   jax.ShapeDtypeStruct(im.shape, im.dtype)],
        interpret=interpret,
    )
    return fn(re, im, green)
