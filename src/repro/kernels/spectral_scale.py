"""Pallas TPU kernel: fused Green-function multiply + normalization.

The spectral convolution u_hat = f_hat * G_hat * norm is the only O(N^3)
pointwise pass of the solve; fusing the complex scale with the
normalization halves its HBM traffic vs two separate elementwise ops.

Complex data is carried as separate (re, im) f32 planes (TPU-native: the
MXU/VPU have no complex type).  Blocks are (rows_tile, lane_tile) VMEM
tiles over a (rows, lanes) view, 8x128-aligned.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 256)


def _kernel(re_ref, im_ref, g_ref, out_re_ref, out_im_ref, *, scale):
    g = g_ref[...] * scale
    out_re_ref[...] = re_ref[...] * g
    out_im_ref[...] = im_ref[...] * g


def spectral_scale(re, im, green, scale: float,
                   block=DEFAULT_BLOCK, interpret=True):
    """re/im/green: (rows, lanes) f32 -> scaled (re, im)."""
    rows, lanes = re.shape
    br = min(block[0], rows)
    bl = min(block[1], lanes)
    grid = (pl.cdiv(rows, br), pl.cdiv(lanes, bl))
    spec = pl.BlockSpec((br, bl), lambda i, j: (i, j))
    fn = pl.pallas_call(
        partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(re.shape, re.dtype),
                   jax.ShapeDtypeStruct(im.shape, im.dtype)],
        interpret=interpret,
    )
    return fn(re, im, green)
