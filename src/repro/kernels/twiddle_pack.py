"""Pallas TPU kernel: DCT/DST post-twiddle (the r2r "shuffle" hot loop).

After the length-2M complex FFT, every real transform applies a per-mode
twiddle and packs the real result (section II / transforms.py):

    y[r, k] = cos[k] * re[r, k] + sin[k] * im[r, k]

Fusing the two multiplies, the add and the pack keeps the pass at one HBM
read per operand and one write -- flups' pack() + shuffle() in a single
VMEM-resident kernel.  cos/sin are broadcast along rows (one VMEM copy per
lane tile).

On power-of-two lengths this pass no longer runs standalone in the solve:
``fft_stockham_twiddle`` executes the same epilogue in the FFT's final-
stage registers (DESIGN.md #9).  This kernel remains the non-pow2 path
and the unit the fused variant is validated against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 256)


def _kernel(re_ref, im_ref, cos_ref, sin_ref, out_ref):
    out_ref[...] = (cos_ref[...] * re_ref[...] +
                    sin_ref[...] * im_ref[...])


def twiddle_pack(re, im, cos, sin, block=DEFAULT_BLOCK, interpret=True):
    """re/im: (rows, k); cos/sin: (k,) -> y (rows, k)."""
    rows, k = re.shape
    br = min(block[0], rows)
    bk = min(block[1], k)
    grid = (pl.cdiv(rows, br), pl.cdiv(k, bk))
    mat = pl.BlockSpec((br, bk), lambda i, j: (i, j))
    vec = pl.BlockSpec((1, bk), lambda i, j: (0, j))
    fn = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[mat, mat, vec, vec],
        out_specs=mat,
        out_shape=jax.ShapeDtypeStruct(re.shape, re.dtype),
        interpret=interpret,
    )
    return fn(re, im, cos.reshape(1, -1), sin.reshape(1, -1))
