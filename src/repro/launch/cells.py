"""(architecture x shape x mesh) cell construction for the dry-run.

``build_cell`` returns a jitted entry point plus ShapeDtypeStruct arguments
(with NamedShardings attached): ``.lower(*args).compile()`` is the dry-run.
No parameters or activations are ever materialized.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, SHAPES
from repro.core.comm import CommConfig
from repro.models import transformer as tf
from repro.models.common import ModelConfig
from repro.training import optimizer as opt
from repro.training.train_step import TrainState, train_step_fn, state_specs


@dataclass
class Cell:
    arch: str
    shape: str
    fn: Any                  # jitted callable
    args: tuple              # ShapeDtypeStructs (sharded)
    meta: dict


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _tree_sds(tree_shapes, tree_specs, mesh):
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp),
        tree_shapes, tree_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _dp_spec(mesh, batch=None):
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if batch is not None:
        n = 1
        for a in dp:
            n *= mesh.shape[a]
        if batch % n != 0:
            return ()          # replicate tiny batches (e.g. long_500k B=1)
    return dp


def model_flops(cfg: ModelConfig, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE); decode uses the
    2 N per-token forward cost."""
    n = _active_params(cfg)
    per_tok = 6.0 * n if kind == "train" else 2.0 * n
    return per_tok * tokens


def _active_params(cfg: ModelConfig) -> float:
    """Active (per-token) parameter count."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        s = cfg.ssm
        din = s.d_inner(d)
        nh = s.n_heads(d)
        per = d * (2 * din + 2 * s.d_state + nh) + din * d
        return emb + L * per
    att = d * cfg.n_heads * cfg.d_head * 2 + \
        d * cfg.n_kv * cfg.d_head * 2
    gate = 1 if cfg.act in ("swiglu", "geglu") else 0
    mlp = d * cfg.d_ff * (2 + gate)
    if cfg.family == "moe":
        mlp = mlp * cfg.moe.top_k + d * cfg.moe.n_experts  # router
    per = att + mlp
    if cfg.family == "hybrid":
        dr = cfg.hybrid.d_rnn or d
        rec = d * dr * 2 + dr * dr * 2 + dr * d + d * cfg.d_ff * (2 + gate)
        n_att = cfg.n_layers // 3
        return emb + n_att * per + (L - n_att) * rec
    if cfg.family == "encdec":
        return emb + L * (per + att) + cfg.n_enc_layers * per
    return emb + L * per


def build_cell(arch: str, shape_name: str, mesh,
               comm: CommConfig = CommConfig(),
               adam: opt.AdamWConfig | None = None,
               remat: str | None = None,
               extra_cfg: dict | None = None) -> Cell:
    if arch == "flups-poisson":
        return _build_poisson_cell(shape_name, mesh, comm)
    cfg = get_config(arch)
    if remat is not None:
        import dataclasses as dc
        cfg = dc.replace(cfg, remat=remat)
    if extra_cfg:
        import dataclasses as dc
        cfg = dc.replace(cfg, **extra_cfg)
    sh = SHAPES[shape_name]
    ms = dict(mesh.shape)
    B, S = sh.global_batch, sh.seq_len
    dp = _dp_spec(mesh, B)
    dtype_tok = jnp.int32

    pspecs = tf.param_specs(cfg, ms)
    pshapes = jax.eval_shape(partial(tf.init_params, cfg=cfg),
                             jax.random.PRNGKey(0))
    meta = {"arch": arch, "shape": shape_name, "kind": sh.kind,
            "global_batch": B, "seq_len": S,
            "mesh": tuple(mesh.shape.items()),
            "model_flops": model_flops(
                cfg, B * S if sh.kind != "decode" else B, sh.kind)}

    if sh.kind == "train":
        adam = adam or opt.AdamWConfig()
        sspec = state_specs(cfg, ms)
        sshapes = jax.eval_shape(
            lambda k: TrainState(tf.init_params(k, cfg),
                                 opt.init_opt_state(
                                     tf.init_params(k, cfg)), None),
            jax.random.PRNGKey(0))
        state_sds = _tree_sds(sshapes, sspec, mesh)
        batch = {"inputs": _sds((B, S), dtype_tok, mesh, P(dp, None)),
                 "labels": _sds((B, S), dtype_tok, mesh, P(dp, None)),
                 "mask": _sds((B, S), jnp.float32, mesh, P(dp, None))}
        if cfg.n_frontend_tokens:
            batch["frontend"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                     jnp.float32, mesh, P(dp, None, None))
        step = train_step_fn(cfg, adam=adam, comm=comm, mesh=mesh)
        fn = jax.jit(step, donate_argnums=(0,))
        return Cell(arch, shape_name, fn, (state_sds, batch), meta)

    params_sds = _tree_sds(pshapes, pspecs, mesh)

    if sh.kind == "prefill":
        tokens = _sds((B, S), dtype_tok, mesh, P(dp, None))
        args = [params_sds, tokens]
        if cfg.n_frontend_tokens:
            args.append(_sds((B, cfg.n_frontend_tokens, cfg.d_model),
                             jnp.float32, mesh, P(dp, None, None)))

            def fwd(p, t, f):
                return tf.forward(p, cfg, t, f, comm, mesh)
        else:
            def fwd(p, t):
                return tf.forward(p, cfg, t, None, comm, mesh)
        return Cell(arch, shape_name, jax.jit(fwd), tuple(args), meta)

    # decode: one new token with caches of length S
    cshapes = jax.eval_shape(partial(tf.init_caches, cfg, B, S))
    cspecs = tf.cache_specs(cfg, ms, cshapes, dp=dp)
    caches_sds = _tree_sds(cshapes, cspecs, mesh)
    token = _sds((B, 1), dtype_tok, mesh, P(dp, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def dec(p, t, c, pos):
        return tf.decode_step(p, cfg, t, c, pos, comm, mesh)

    fn = jax.jit(dec, donate_argnums=(2,))
    return Cell(arch, shape_name, fn, (params_sds, token, caches_sds, pos),
                meta)


def _build_poisson_cell(shape_name, mesh, comm):
    from repro.core.comm import autotune_candidates
    from repro.configs.flups_poisson import CONFIG
    from repro.core.solver import get_solver
    multi = "pod" in mesh.shape
    # precedence: a launcher comm that differs from the stock default wins;
    # otherwise the arch config's knobs apply (comm="auto" = plan-time
    # tuner, a capability the dryrun CLI cannot express)
    if comm == CommConfig():
        comm = ("auto" if CONFIG.comm == "auto"
                else CommConfig(CONFIG.comm, CONFIG.comm_chunks))
    # single-pod meshes run CONFIG.batch fields as ONE batched multi-RHS
    # solve (in-block batch axis); multi-pod shards the batch over "pod"
    local_batch = not multi and CONFIG.batch > 1
    batch = CONFIG.batch if (multi or local_batch) else None
    # the global plan cache makes cell re-construction (reprobe sweeps,
    # repeated dryruns over the same mesh) hit one live solver instance
    solver = get_solver(
        (CONFIG.n,) * 3, 1.0, CONFIG.bcs, layout=CONFIG.layout,
        green_kind=CONFIG.green, mesh=mesh,
        axes=("data", "model"), comm=comm,
        batch_axis="pod" if multi else None, lazy_green=True,
        engine=CONFIG.engine, doubling=CONFIG.doubling,
        relayout=CONFIG.relayout,
        # guided search derives its own predictor-ranked shortlist from the
        # solver's plan; only brute mode pins the exhaustive candidate grid
        autotune_search=CONFIG.comm_autotune_search,
        autotune_candidates=(None if CONFIG.comm_autotune_search == "guided"
                             else autotune_candidates(
            CONFIG.comm_autotune_max_chunks,
            folds=(("pack", "unpack") if CONFIG.relayout == "scheduled"
                   else ("pack",)))),
        autotune_cache=CONFIG.comm_autotune_cache or None,
        autotune_budget=CONFIG.comm_autotune_budget_s or None,
        # comm="auto" must time the rank it will run: the in-block batch
        autotune_batch=CONFIG.batch if local_batch else None,
        verify=CONFIG.verify or None, verify_rtol=CONFIG.verify_rtol)
    f_sds = jax.ShapeDtypeStruct(
        solver.padded_input_shape(batch), jnp.float32,
        sharding=NamedSharding(mesh, solver.input_spec(local_batch)))
    g_sds = jax.ShapeDtypeStruct(
        solver._green_np.shape, solver._green_np.dtype,
        sharding=NamedSharding(mesh, solver.g_spec))
    n = CONFIG.n
    meta = {"arch": "flups-poisson", "shape": shape_name, "kind": "solve",
            "grid": n, "mesh": tuple(mesh.shape.items()),
            "batch": batch or 1,
            # forward + backward 3-D FFT on the doubled (2n)^3 domain
            "model_flops": (batch or 1) * 2 * 5 * (2 * n) ** 3
            * np.log2((2 * n) ** 3)}
    return Cell("flups-poisson", shape_name, solver.jit_for(local_batch),
                (f_sds, g_sds), meta)
