import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-moe-235b-a22b \
        --shape train_4k --mesh single --comm pipelined

Outputs one JSON record per cell to results/dryrun/<tag>.jsonl with
memory_analysis, cost_analysis, collective bytes (parsed from the
post-partitioning HLO) and the roofline terms.

The XLA_FLAGS line above MUST precede any jax import: device count locks
on first backend initialization.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, arch_shapes
from repro.core.comm import CommConfig
from repro.launch import hlo_stats
from repro.launch.cells import build_cell
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16,
                               make_production_mesh)


def roofline_terms(flops, bytes_acc, coll_bytes, n_chips):
    """The three roofline times (seconds), whole-step totals."""
    t_comp = flops / (n_chips * PEAK_FLOPS_BF16)
    t_mem = bytes_acc / (n_chips * HBM_BW)
    # collective bytes are summed over per-device program operands; each
    # device drives its own links: per-chip bytes / per-chip link bw
    t_coll = coll_bytes / ICI_BW_PER_LINK
    return t_comp, t_mem, t_coll


def run_cell(arch, shape_name, mesh, comm, record_hlo=False, remat=None,
             extra_cfg=None):
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v
    t0 = time.perf_counter()
    cell = build_cell(arch, shape_name, mesh, comm=comm, remat=remat,
                      extra_cfg=extra_cfg)
    with jax.sharding.set_mesh(mesh):
        lowered = cell.fn.lower(*cell.args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    rec = dict(cell.meta)
    rec.update({"comm": comm.strategy, "n_chips": n_chips,
                "t_lower_s": round(t_lower, 2),
                "t_compile_s": round(t_compile, 2)})

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        rec["cost_raw"] = {"flops": flops, "bytes_accessed": bytes_acc}
    except Exception as e:  # pragma: no cover
        flops = bytes_acc = 0.0
        rec["cost_raw"] = {"error": str(e)}

    hlo = compiled.as_text()
    coll = hlo_stats.collective_stats(hlo)
    rec["collectives_raw"] = coll
    rec["op_census"] = hlo_stats.op_census(hlo)

    # scan-corrected costs (XLA counts while bodies once; see flops_probe)
    from repro.launch.flops_probe import probed_costs
    try:
        corr = probed_costs(arch, shape_name, mesh, comm, remat=remat,
                            extra_cfg=extra_cfg)
        rec["cost"] = corr
        flops, bytes_acc = corr["flops"], corr["bytes"]
        coll_bytes = corr["coll_bytes"]
    except Exception as e:
        rec["cost"] = {"probe_error": f"{type(e).__name__}: {e}"}
        coll_bytes = coll["total_bytes"]

    # cost_analysis flops on the partitioned module are per-device
    total_flops = flops * n_chips
    per_dev_bytes = bytes_acc
    t_comp, t_mem, t_coll = roofline_terms(
        total_flops, per_dev_bytes * n_chips, coll_bytes, n_chips)
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = cell.meta.get("model_flops", 0.0)
    rec["roofline"] = {
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": total_flops,
        "useful_flops_frac": (mf / total_flops) if total_flops else None,
        "roofline_frac": (mf / (n_chips * PEAK_FLOPS_BF16)) /
        max(t_comp, t_mem, t_coll) if total_flops else None,
    }
    if record_hlo:
        rec["hlo_len"] = len(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--comm", default="a2a",
                    choices=["a2a", "pipelined", "fused", "overlap"])
    ap.add_argument("--chunks", type=int, default=2,
                    help="pipelined/overlap granularity (paper's n_batch)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig overrides, e.g. --set attn_block=2048")
    args = ap.parse_args()

    extra = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                v = {"true": True, "false": False}.get(v.lower(), v)
        extra[k] = v

    archs = list(ALL_ARCHS) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    comm = CommConfig(strategy=args.comm, n_chunks=args.chunks)
    os.makedirs(args.out, exist_ok=True)
    tag = args.tag or f"{args.arch}_{args.shape}_{args.mesh}_{args.comm}"
    tag = tag.replace("/", "_").replace(",", "+")[:120]
    path = os.path.join(args.out, tag + ".jsonl")

    wrote = 0
    with open(path, "a") as f:
        for multi in meshes:
            mesh = make_production_mesh(multi_pod=multi)
            for arch in archs:
                shapes = ([s.name for s in arch_shapes(arch)]
                          or ["solve"])
                if args.shape != "all":
                    shapes = [s for s in shapes if s in
                              args.shape.split(",")]
                    if arch == "flups-poisson" and "solve" in \
                            args.shape.split(","):
                        shapes = ["solve"]
                for shape_name in shapes:
                    label = f"{arch}/{shape_name}/" \
                        f"{'multi' if multi else 'single'}"
                    try:
                        rec = run_cell(arch, shape_name, mesh, comm,
                                       remat=args.remat,
                                       extra_cfg=extra or None)
                        rec["status"] = "ok"
                        rec["extra_cfg"] = extra
                        print(f"[dryrun] OK  {label}  "
                              f"compile={rec['t_compile_s']}s  "
                              f"dominant={rec['roofline']['dominant']}",
                              flush=True)
                    except Exception as e:
                        rec = {"arch": arch, "shape": shape_name,
                               "mesh_multi": multi, "status": "fail",
                               "error": f"{type(e).__name__}: {e}",
                               "trace": traceback.format_exc()[-2000:]}
                        print(f"[dryrun] FAIL {label}: {e}", flush=True)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    wrote += 1
    print(f"[dryrun] wrote {wrote} records to {path}")


if __name__ == "__main__":
    main()
