"""Scan-corrected HLO costs.

XLA's ``cost_analysis`` counts a while-loop body ONCE regardless of trip
count (verified in tests/test_launch.py), which silently undercounts every
scanned layer stack.  Costs are affine in layer count, so we lower small
python-unrolled probes and extrapolate exactly:

  uniform stacks:  cost(L) = c1 + (L - 1) * (c2 - c1)
  hybrid:          cost(L) = c3 + (g - 1) * (c6 - c3) + (c5 - c3)
                   (probes at 3, 6 and 5 layers; 5 = one group + the
                    2-layer remainder of the 38-layer pattern)
  enc-dec:         cost = c11 + (E-1)(c21 - c11) + (D-1)(c12 - c11)

Inner (chunk) scans are unrolled in the probes (cfg.unroll_inner) so the
SSD chunk loop is fully counted.  The same correction applies to
bytes-accessed and to HLO-parsed collective bytes (the while body appears
once in the HLO text too).
"""
from __future__ import annotations

import jax

from repro.configs import get_config
from repro.core.comm import CommConfig
from repro.launch import hlo_stats
from repro.launch.cells import build_cell


def _cell_costs(arch, shape_name, mesh, comm, remat, extra):
    cell = build_cell(arch, shape_name, mesh, comm=comm, remat=remat,
                      extra_cfg=extra)
    with jax.sharding.set_mesh(mesh):
        compiled = cell.fn.lower(*cell.args).compile()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    coll = hlo_stats.collective_stats(txt)
    return {"flops": float(cost.get("flops", 0.0)) +
            hlo_stats.fft_flops(txt),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll["total_bytes"]),
            "coll_count": float(coll["total_count"])}


def _lin(c_lo, c_hi, n_lo_units, extra_units):
    """c_lo at n_lo_units, slope from (c_hi - c_lo): add extra_units."""
    return {k: max(c_lo[k] + extra_units * (c_hi[k] - c_lo[k]), 0.0)
            for k in c_lo}


def probed_costs(arch, shape_name, mesh, comm: CommConfig, remat=None,
                 extra_cfg=None):
    """Scan-corrected {flops, bytes, coll_bytes} per device for the cell."""
    extra_cfg = dict(extra_cfg or {})
    if arch == "flups-poisson":
        # the pencil solver is python-structured: no while-loop undercount
        return _cell_costs(arch, shape_name, mesh, comm, remat, None)
    cfg = get_config(arch)
    probe = dict(extra_cfg)
    probe.update({"scan_layers": False, "unroll_inner": True})

    if cfg.family == "hybrid":
        c3 = _cell_costs(arch, shape_name, mesh, comm, remat,
                         {**probe, "n_layers": 3})
        c6 = _cell_costs(arch, shape_name, mesh, comm, remat,
                         {**probe, "n_layers": 6})
        c5 = _cell_costs(arch, shape_name, mesh, comm, remat,
                         {**probe, "n_layers": 5})
        g = cfg.n_layers // len(cfg.hybrid.pattern)
        rem = cfg.n_layers - g * len(cfg.hybrid.pattern)
        out = {k: c3[k] + (g - 1) * (c6[k] - c3[k]) for k in c3}
        if rem:
            out = {k: out[k] + (c5[k] - c3[k]) for k in out}
        return {k: max(v, 0.0) for k, v in out.items()}
    if cfg.family == "encdec":
        c11 = _cell_costs(arch, shape_name, mesh, comm, remat,
                          {**probe, "n_layers": 1, "n_enc_layers": 1})
        c21 = _cell_costs(arch, shape_name, mesh, comm, remat,
                          {**probe, "n_layers": 1, "n_enc_layers": 2})
        c12 = _cell_costs(arch, shape_name, mesh, comm, remat,
                          {**probe, "n_layers": 2, "n_enc_layers": 1})
        return {k: max(c11[k] + (cfg.n_enc_layers - 1) * (c21[k] - c11[k])
                       + (cfg.n_layers - 1) * (c12[k] - c11[k]), 0.0)
                for k in c11}
    c1 = _cell_costs(arch, shape_name, mesh, comm, remat,
                     {**probe, "n_layers": 1})
    c2 = _cell_costs(arch, shape_name, mesh, comm, remat,
                     {**probe, "n_layers": 2})
    return _lin(c1, c2, 1, cfg.n_layers - 1)
