"""HLO parsing: collective bytes + op census from compiled/lowered text."""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

# HLO text format: ``... = f32[..] all-to-all(f32[..] %a, ...)`` or async
# -start/-done pairs (count the start only)
_HLO_COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")
# StableHLO/MLIR text format: ``%5 = "stablehlo.all_to_all"(%4) ... :
# (tensor<AxBxcomplex<f32>>) -> tensor<...>``
_MLIR_COLLECTIVE_RE = re.compile(
    r'"stablehlo\.(all_to_all|all_gather|all_reduce|reduce_scatter|'
    r'collective_permute)"')
_MLIR_TENSOR_RE = re.compile(
    r"tensor<((?:[0-9]+x)*)([a-z][a-z0-9]*(?:<[a-z0-9]+>)?)>")
_MLIR_DTYPE_BYTES = {"complex<f32>": 8, "complex<f64>": 16}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_collective(rhs: str):
    """(op, operand_bytes) of the collective on one ``lhs = rhs`` line of
    HLO or StableHLO text, or None (including async -done halves)."""
    m = _HLO_COLLECTIVE_RE.search(rhs)
    if m is not None:
        if m.group(2) == "-done":
            return None               # async pair: count the start only
        head, _, args = rhs.partition(m.group(0))
        # prefer operand types inline (single-result text format); the
        # operand list ends at the first ")"
        shapes = _SHAPE_RE.findall(args.split(")", 1)[0])
        nbytes = sum(_shape_bytes(d, dims) for d, dims in shapes)
        if nbytes == 0:
            # tuple/name-only operand format: use the result type(s) before
            # the opcode (a2a/permute preserve total bytes; gather outputs
            # upper-bound the wire bytes)
            shapes = _SHAPE_RE.findall(head)
            nbytes = sum(_shape_bytes(d, dims) for d, dims in shapes)
        return m.group(1), nbytes
    m = _MLIR_COLLECTIVE_RE.search(rhs)
    if m is not None:
        # operand types live in the trailing ``: (operands) -> results``
        # signature; bill the operand side
        operand = rhs.rsplit(":", 1)[-1].split("->", 1)[0]
        nbytes = 0
        for dims, dt in _MLIR_TENSOR_RE.findall(operand):
            n = 1
            for d in dims.split("x"):
                if d:
                    n *= int(d)
            nbytes += n * _MLIR_DTYPE_BYTES.get(dt, _DTYPE_BYTES.get(dt, 4))
        return m.group(1).replace("_", "-"), nbytes
    return None


def _iter_collectives(hlo_text: str):
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        hit = _line_collective(s.split("=", 1)[1])
        if hit is not None:
            yield hit


def collective_stats(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in an HLO module text.

    Handles both ``x = f32[..] all-to-all(f32[..] %a, ...)`` (operand types
    inline) and start/done pairs (async collectives are counted once, on
    the -start op).
    """
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for op, nbytes in _iter_collectives(hlo_text):
        if op not in out:
            continue
        out[op]["count"] += 1
        out[op]["bytes"] += nbytes
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def comm_bytes_stats(hlo_text: str) -> dict:
    """Per-collective operand bytes in PROGRAM ORDER (lowered StableHLO or
    HLO text, pre-scheduling, so line order == trace order).

    The valid-extent / deferred-doubling acceptance probe: a pruned plan's
    first forward topology switch must ship fewer bytes than the dense
    (up-front Hockney doubling) plan's, which this makes assertable as
    ``comm_bytes_stats(pruned)["per_collective"][0]["bytes"] <
    comm_bytes_stats(dense)["per_collective"][0]["bytes"]``.

    Returns ``per_collective`` (list of ``{op, bytes}`` dicts in program
    order), ``first_bytes``/``last_bytes`` (conveniences for the first and
    last entries, 0 when none), and ``total_bytes``.  Chunked strategies
    emit one entry per chunk; group consecutive entries of one switch by
    comparing against ``CommConfig.n_chunks`` if needed.
    """
    per = [{"op": op, "bytes": nbytes}
           for op, nbytes in _iter_collectives(hlo_text)]
    return {
        "per_collective": per,
        "first_bytes": per[0]["bytes"] if per else 0,
        "last_bytes": per[-1]["bytes"] if per else 0,
        "total_bytes": sum(p["bytes"] for p in per),
    }


_FFTLEN_RE = re.compile(r"fft_length=\{([0-9,]+)\}")


def fft_flops(hlo_text: str) -> float:
    """Analytic FLOPs of HLO fft ops (XLA cost_analysis reports ~0 for
    them): 5 * batch * n * log2(n) per transform (complex radix-2)."""
    import math
    total = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        if " fft(" not in s or "=" not in s:
            continue
        lenm = _FFTLEN_RE.search(s)
        if not lenm:
            continue
        flen = 1
        for d in lenm.group(1).split(","):
            flen *= int(d)
        head = s.split(" fft(", 1)[0]
        shapes = _SHAPE_RE.findall(head)
        if not shapes:
            continue
        n_elems = 1
        for d in (shapes[-1][1].split(",") if shapes[-1][1] else []):
            n_elems *= int(d)
        total += 5.0 * n_elems * max(math.log2(max(flen, 2)), 1.0)
    return total


_A2A_RE = re.compile(r"all[-_]to[-_]all")
_FFT_RE = re.compile(r"stablehlo\.fft|call @fft|\bfft\(")


def comm_interleave_stats(text: str) -> dict:
    """Program-order census of topology-switch collectives vs transform
    compute, from lowered StableHLO or HLO text (pre-scheduling, so line
    order == trace order).

    Returns ``all_to_all`` (collective count), ``fft`` (transform ops seen
    before the last collective), ``gaps_with_compute`` (consecutive-
    collective pairs with >= 1 fft between them -- the ``overlap``
    strategy's signature: chunk k's transform issued between chunk k and
    k+1's collectives) and ``adjacent_pairs`` (pairs with none).
    """
    # census per function, then keep the one holding the collectives (the
    # entry computation; fft helper funcs may precede @main in the module)
    per_func = [[]]
    for line in text.splitlines():
        s = line.strip()
        if "func.func" in s or s.startswith("ENTRY "):
            per_func.append([])
            continue
        if _A2A_RE.search(s):
            if "-done" in s:    # async pair: count the start only
                continue
            per_func[-1].append("a2a")
        elif _FFT_RE.search(s):
            per_func[-1].append("fft")
    seq = max(per_func, key=lambda f: f.count("a2a"))
    n_a2a = seq.count("a2a")
    gaps = adjacent = 0
    fft_before_last = 0
    pending_fft = 0
    seen_first = False
    for tok in seq:
        if tok == "fft":
            if seen_first:
                pending_fft += 1
            continue
        if seen_first:
            if pending_fft:
                gaps += 1
                fft_before_last += pending_fft
            else:
                adjacent += 1
        seen_first = True
        pending_fft = 0
    return {"all_to_all": n_a2a, "fft": fft_before_last,
            "gaps_with_compute": gaps, "adjacent_pairs": adjacent}


_TRANSPOSE_RE = re.compile(r"stablehlo\.transpose|=\s+\S+\s+transpose\(")
_COLL_ANY_RE = re.compile(
    r"all[-_]to[-_]all|all[-_]gather|all[-_]reduce|reduce[-_]scatter|"
    r"collective[-_]permute")


def _tensor_bytes(line: str) -> int:
    """Byte size of the first tensor type on an HLO/StableHLO line."""
    m = _MLIR_TENSOR_RE.search(line)
    if m is not None:
        dims, dt = m.groups()
        n = 1
        for d in dims.split("x"):
            if d:
                n *= int(d)
        return n * _MLIR_DTYPE_BYTES.get(dt, _DTYPE_BYTES.get(dt, 4))
    m = _SHAPE_RE.search(line)
    if m is not None:
        return _shape_bytes(m.group(1), m.group(2))
    return 0


def transpose_stats(text: str) -> dict:
    """Program-order census of relayout (transpose) ops vs transform and
    collective ops, from lowered StableHLO or HLO text (pre-scheduling, so
    line order == trace order).

    The layout-scheduling acceptance probe (DESIGN.md #9).  Each transpose
    is classified as

    * ``edge``         -- before the first or after the last transform of
                          the pipeline: the two adapters between the user's
                          natural layout and the scheduled one;
    * ``switch_fused`` -- attributable to a topology switch: no transform
                          sits between it and an adjacent collective, and
                          it is that collective's FIRST attributed
                          transpose (the one relayout a switch's unpack
                          must perform anyway);
    * ``standalone``   -- everything else: transposes strictly between two
                          transforms with no collective to fold into, plus
                          any attributed to a collective beyond the
                          1-per-collective budget (the baseline pipeline's
                          moveaxis round trips put TWO on every switch).

    The scheduled distributed solve must show ``standalone == 0``; the
    baseline shows one per switch.  ``*_bytes`` totals estimate the HBM
    traffic of each class (operand bytes of the transpose ops).

    Census limitation: a CHUNKED ``overlap`` switch under ``fold="unpack"``
    interleaves per-chunk unpack transposes with per-chunk transforms
    (``... C C T F T F ...``) -- on a linear token stream the later
    chunks' transposes are indistinguishable from standalone relayouts and
    are (conservatively) counted as such.  Gates asserting
    ``standalone == 0`` must therefore run the census on monolithic or
    ``fold="pack"`` configurations (as ``bench_solve.py --check`` and
    ``tests/test_layout.py`` do); the autotuner is still free to PICK
    overlap+unpack at runtime.
    """
    per_func = [[]]
    for line in text.splitlines():
        s = line.strip()
        if "func.func" in s or s.startswith("ENTRY "):
            per_func.append([])
            continue
        if _COLL_ANY_RE.search(s):
            if "-done" in s:        # async pair: count the start only
                continue
            per_func[-1].append(("C", 0))
        elif _FFT_RE.search(s):
            per_func[-1].append(("F", 0))
        elif _TRANSPOSE_RE.search(s):
            per_func[-1].append(("T", _tensor_bytes(s)))
    # the entry computation: most collectives, then most transposes (the
    # single-process pipeline has no collectives at all)
    seq = max(per_func, key=lambda f: (sum(1 for t, _ in f if t == "C"),
                                       sum(1 for t, _ in f if t == "T")))
    kinds = [t for t, _ in seq]
    f_idx = [i for i, t in enumerate(kinds) if t == "F"]
    out = {"total": 0, "edge": 0, "switch_fused": 0, "standalone": 0,
           "total_bytes": 0, "edge_bytes": 0, "switch_fused_bytes": 0,
           "standalone_bytes": 0, "collectives": kinds.count("C"),
           "transforms": len(f_idx)}
    first_f = f_idx[0] if f_idx else len(kinds)
    last_f = f_idx[-1] if f_idx else -1
    budget_used: dict = {}

    def _adjacent_collective(i: int):
        """Index of a collective reachable from position ``i`` without
        crossing a transform, or None."""
        for j in range(i - 1, -1, -1):
            if kinds[j] == "C":
                return j
            if kinds[j] == "F":
                break
        for j in range(i + 1, len(kinds)):
            if kinds[j] == "C":
                return j
            if kinds[j] == "F":
                break
        return None

    for i, (t, nbytes) in enumerate(seq):
        if t != "T":
            continue
        out["total"] += 1
        out["total_bytes"] += nbytes
        if i < first_f or i > last_f:
            cls = "edge"
        else:
            c = _adjacent_collective(i)
            if c is not None and not budget_used.get(c):
                budget_used[c] = True
                cls = "switch_fused"
            else:
                cls = "standalone"
        out[cls] += 1
        out[cls + "_bytes"] += nbytes
    return out


def op_census(hlo_text: str, ops=("fusion", "custom-call", "dot",
                                  "convolution", "scatter", "transpose",
                                  "copy")) -> dict:
    counts = {}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+\S+\s+([a-z\-]+)\(", line.strip())
        if m and m.group(1) in ops:
            counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return counts
