"""Production mesh construction.

Never touches jax device state at import time: callers create meshes via
the functions below.  The dry-run target is a TPU v5e-class fabric:
  single pod:  (16, 16)     -> ("data", "model"),   256 chips
  multi  pod:  (2, 16, 16)  -> ("pod", "data", "model"), 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data=1, n_model=1):
    """Small mesh for CPU validation runs (host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# hardware constants for the roofline (TPU v5e-class, per chip)
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW_PER_LINK = 50e9       # B/s (one direction, per link)
