"""Render EXPERIMENTS.md sections from the dry-run JSONL records.

    PYTHONPATH=src python -m repro.launch.report > results/roofline.md
"""
from __future__ import annotations

import argparse
import glob
import json

from repro.launch.mesh import PEAK_FLOPS_BF16

HBM_PER_CHIP = 16e9   # v5e-class


def load(patterns):
    recs = {}
    order = []
    paths = []
    for pattern in patterns.split():
        paths.extend(sorted(glob.glob(pattern)))
    for path in paths:
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                mesh = dict(r.get("mesh", []))
                key = (r["arch"], r["shape"],
                       "multi" if "pod" in mesh else "single")
                if key not in recs:
                    order.append(key)
                recs[key] = r
    return recs, order


def fmt_bytes(b):
    return f"{b / 1e9:.1f}"


def dryrun_table(recs, order, mesh_sel):
    lines = [
        "| arch | shape | status | compile s | args GB/dev | temp GB/dev "
        "| fits 16G | coll count | coll GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key in order:
        arch, shape, mesh = key
        if mesh != mesh_sel:
            continue
        r = recs[key]
        if r.get("status") != "ok":
            lines.append(f"| {arch} | {shape} | FAIL | - | - | - | - | - | - |")
            continue
        m = r.get("memory", {})
        args_gb = m.get("argument_size_in_bytes", 0) / 1e9
        temp_gb = m.get("temp_size_in_bytes", 0) / 1e9
        fits = "yes" if (args_gb + temp_gb) * 1e9 < HBM_PER_CHIP else "NO"
        c = r.get("cost", {})
        coll_b = c.get("coll_bytes", 0) / 1e9
        coll_n = int(c.get("coll_count", 0))
        lines.append(
            f"| {arch} | {shape} | ok | {r['t_compile_s']} | "
            f"{args_gb:.1f} | {temp_gb:.1f} | {fits} | {coll_n} | "
            f"{coll_b:.2f} |")
    return "\n".join(lines)


def roofline_table(recs, order, mesh_sel="single"):
    lines = [
        "| arch | shape | t_comp s | t_mem s | t_coll s | dominant | "
        "MODEL_FLOPs | MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key in order:
        arch, shape, mesh = key
        if mesh != mesh_sel:
            continue
        r = recs[key]
        if r.get("status") != "ok":
            continue
        rf = r.get("roofline", {})
        uf = rf.get("useful_flops_frac")
        frac = rf.get("roofline_frac")
        lines.append(
            f"| {arch} | {shape} | {rf.get('t_compute_s', 0):.3g} | "
            f"{rf.get('t_memory_s', 0):.3g} | "
            f"{rf.get('t_collective_s', 0):.3g} | {rf.get('dominant')} | "
            f"{rf.get('model_flops', 0):.3g} | "
            f"{uf and round(uf, 3)} | {frac and round(frac, 4)} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--glob",
        default="results/dryrun/baseline_*.jsonl results/dryrun/z*.jsonl")
    args = ap.parse_args()
    recs, order = load(args.glob)
    n_ok = sum(1 for r in recs.values() if r.get("status") == "ok")
    print(f"## Dry-run summary ({n_ok}/{len(recs)} cells ok)\n")
    for mesh in ("single", "multi"):
        keys = [k for k in order if k[2] == mesh]
        if not keys:
            continue
        print(f"### {mesh}-pod mesh\n")
        print(dryrun_table(recs, order, mesh))
        print()
    print("## Roofline (single-pod)\n")
    print(roofline_table(recs, order))


if __name__ == "__main__":
    main()
