import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Re-run the scan-corrected cost probes for existing dry-run records
(after the HLO collective-parser fix) and write corrected records.

Reuses memory_analysis / compile times from the original records; only
cost/collectives/roofline are recomputed.

    PYTHONPATH=src python -m repro.launch.reprobe \
        --in results/dryrun/baseline_single.jsonl \
        --out results/dryrun/zcorr_single.jsonl
"""
import argparse
import json

import jax

from repro.core.comm import CommConfig
from repro.launch.dryrun import roofline_terms
from repro.launch.flops_probe import probed_costs
from repro.launch.mesh import PEAK_FLOPS_BF16, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", required=True)
    ap.add_argument("--out", required=True)
    ap.add_argument("--remat", default="full")
    args = ap.parse_args()

    recs = [json.loads(l) for l in open(args.inp) if l.strip()]
    mesh_single = None
    mesh_multi = None
    done = set()
    if os.path.exists(args.out):
        for l in open(args.out):
            r = json.loads(l)
            done.add((r["arch"], r["shape"]))
    with open(args.out, "a") as f:
        for r in recs:
            if r.get("status") != "ok" or (r["arch"], r["shape"]) in done:
                continue
            mesh_d = dict(r["mesh"])
            multi = "pod" in mesh_d
            if multi:
                mesh_multi = mesh_multi or make_production_mesh(
                    multi_pod=True)
                mesh = mesh_multi
            else:
                mesh_single = mesh_single or make_production_mesh()
                mesh = mesh_single
            comm = CommConfig(strategy=r.get("comm", "a2a"))
            try:
                corr = probed_costs(r["arch"], r["shape"], mesh, comm,
                                    remat=args.remat)
            except Exception as e:
                print(f"[reprobe] FAIL {r['arch']}/{r['shape']}: {e}",
                      flush=True)
                continue
            n_chips = r["n_chips"]
            t_comp, t_mem, t_coll = roofline_terms(
                corr["flops"] * n_chips, corr["bytes"] * n_chips,
                corr["coll_bytes"], n_chips)
            mf = r.get("model_flops", 0.0)
            total_flops = corr["flops"] * n_chips
            dominant = max(("compute", t_comp), ("memory", t_mem),
                           ("collective", t_coll), key=lambda kv: kv[1])[0]
            r["cost"] = corr
            r["roofline"] = {
                "t_compute_s": t_comp, "t_memory_s": t_mem,
                "t_collective_s": t_coll, "dominant": dominant,
                "model_flops": mf,
                "hlo_flops_total": total_flops,
                "useful_flops_frac": (mf / total_flops) if total_flops
                else None,
                "roofline_frac": (mf / (n_chips * PEAK_FLOPS_BF16)) /
                max(t_comp, t_mem, t_coll) if total_flops else None,
            }
            r["reprobed"] = True
            f.write(json.dumps(r) + "\n")
            f.flush()
            print(f"[reprobe] OK {r['arch']}/{r['shape']} "
                  f"{'multi' if multi else 'single'} "
                  f"coll={corr['coll_bytes']/1e9:.1f}GB dom={dominant}",
                  flush=True)


if __name__ == "__main__":
    main()
