"""Poisson solve-as-a-service launcher + threaded client harness.

    PYTHONPATH=src python -m repro.launch.serve --n 32 --tenants 8 \
        --requests 12 --max-batch 8

Stands up a ``repro.serve.PoissonServer`` and drives it with concurrent
tenant threads issuing solve requests over mixed plan keys (the
``examples/serve_lm.py`` idiom, with Poisson plans in place of LM
prompts).  Reports per-tenant latency percentiles, server throughput,
batch occupancy and warm-pool stats; ``--seq`` re-runs the same traffic
under sequential admission (``max_batch=1``) for the coalescing A/B.
``benchmarks/bench_serve.py`` reuses ``run_harness`` for the
BENCH_serve.json sweep.
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time

import numpy as np


def tenant_specs(n: int, engine: str = "xla"):
    """The harness's mixed plan keys: the paper's fully-unbounded
    production case plus an all-periodic plan (different transform
    pipeline, different Green) -- tenants alternate between them, so the
    server must coalesce within keys while isolating across them."""
    from repro.core.bc import BCType
    from repro.serve import PlanSpec
    P, U = BCType.PER, BCType.UNB
    return [
        PlanSpec(shape=(n, n, n), bcs=((U, U),) * 3, engine=engine),
        PlanSpec(shape=(n, n, n), bcs=((P, P),) * 3, engine=engine),
    ]


def run_harness(*, n=32, tenants=8, requests=12, max_batch=8,
                max_delay_ms=4.0, memory_budget_mb=None, workers=1,
                engine="xla", seed=0, check=True, specs=None) -> dict:
    """Drive a fresh server with ``tenants`` concurrent threads, each
    bursting ``requests`` solve requests (open loop -- the heavy-traffic
    regime the server exists for), over mixed plan keys.

    Returns the result payload: wall time, throughput, per-tenant
    percentile summaries, server/pool stats, and -- when ``check`` is on
    -- the max deviation vs per-request reference solves (must be 0.0:
    coalescing and rank padding never perturb a row).
    """
    from repro.serve import PoissonServer

    specs = specs or tenant_specs(n, engine)
    rng = np.random.default_rng(seed)
    traffic = {  # tenant -> (spec, [rhs]) pinned before the clock starts
        f"t{i}": (specs[i % len(specs)],
                  [rng.standard_normal((n, n, n)) for _ in range(requests)])
        for i in range(tenants)}

    server = PoissonServer(max_batch=max_batch, max_delay_ms=max_delay_ms,
                           memory_budget_mb=memory_budget_mb,
                           workers=workers)
    results: dict = {}
    errors: list = []

    def client(name, spec, fs):
        try:
            futs = [server.submit(f, spec, tenant=name) for f in fs]
            results[name] = [fut.result(timeout=600) for fut in futs]
        except Exception as e:  # noqa: BLE001 -- harness-level accounting
            errors.append(f"{name}: {type(e).__name__}: {e}")

    with server:
        # warm every plan + batch rank OUTSIDE the timed window: steady-
        # state serving is the regime of interest, not first-compile cost
        for spec in specs:
            for b in server.batch_ranks:
                fb = [np.zeros((n, n, n)) for _ in range(b)]
                [f.result(timeout=600)
                 for f in [server.submit(x, spec, tenant="_warm")
                           for x in fb]]
        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(name, spec, fs))
                   for name, (spec, fs) in traffic.items()]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall_s = time.perf_counter() - t0
        stats = server.server_stats()
        tstats = {k: v for k, v in server.tenant_stats().items()
                  if k != "_warm"}

    if errors:
        raise RuntimeError("harness clients failed: " + "; ".join(errors))

    total = tenants * requests
    payload = {
        "n": n, "tenants": tenants, "requests_per_tenant": requests,
        "max_batch": max_batch, "max_delay_ms": max_delay_ms,
        "engine": engine, "workers": workers,
        "wall_s": wall_s, "throughput_rps": total / wall_s,
        "mean_batch_occupancy": stats.get("mean_batch_occupancy", 1.0),
        "server": {k: stats[k] for k in
                   ("admitted", "completed", "batches", "deadline_flushes",
                    "full_flushes", "drain_flushes", "padded_rhs")},
        "pool": {k: stats["pool"][k] for k in
                 ("size", "builds", "hits", "evictions", "total_bytes")},
        "solver_cache": stats["solver_cache"],
        "tenants_stats": tstats,
    }
    if check:
        maxdev = 0.0
        for name, (spec, fs) in traffic.items():
            ref = spec.build()
            for f, r in zip(fs, results[name]):
                maxdev = max(maxdev, float(np.max(np.abs(
                    np.asarray(ref.solve(f)) - r.u))))
        payload["max_abs_dev_vs_individual"] = maxdev
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--requests", type=int, default=12,
                    help="requests per tenant (burst-submitted)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="coalescing limit / largest jit batch rank")
    ap.add_argument("--delay-ms", type=float, default=4.0,
                    help="dynamic-batching latency deadline")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="warm-pool memory budget (default unbounded)")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--engine", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--seq", action="store_true",
                    help="also run the sequential-admission baseline "
                         "(max_batch=1) and report the coalescing speedup")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the bit-exactness check vs per-request "
                         "solves")
    ap.add_argument("--json", default=os.environ.get("REPRO_SERVE_LOG"),
                    help="write the full payload to this path")
    args = ap.parse_args(argv)

    import jax
    jax.config.update("jax_enable_x64", True)

    kw = dict(n=args.n, tenants=args.tenants, requests=args.requests,
              max_delay_ms=args.delay_ms, memory_budget_mb=args.budget_mb,
              workers=args.workers, engine=args.engine,
              check=not args.no_check)
    payload = run_harness(max_batch=args.max_batch, **kw)
    print(f"[serve] {args.tenants} tenants x {args.requests} req, "
          f"n={args.n}^3, max_batch={args.max_batch}: "
          f"{payload['throughput_rps']:.1f} req/s, "
          f"occupancy {payload['mean_batch_occupancy']:.2f}, "
          f"wall {payload['wall_s']:.2f}s")
    for name in sorted(payload["tenants_stats"]):
        t = payload["tenants_stats"][name]
        print(f"[serve]   {name}: served {t['served']}, "
              f"p50 {t['p50_ms']:.1f}ms  p95 {t['p95_ms']:.1f}ms  "
              f"p99 {t['p99_ms']:.1f}ms, "
              f"{len(t['degradations'])} degradations")
    if "max_abs_dev_vs_individual" in payload:
        print(f"[serve] max |dev| vs per-request solves: "
              f"{payload['max_abs_dev_vs_individual']:.3e}")
    if args.seq:
        seq = run_harness(max_batch=1, **kw)
        speed = seq["wall_s"] / payload["wall_s"]
        payload["sequential"] = seq
        payload["coalescing_speedup"] = speed
        print(f"[serve] sequential admission: "
              f"{seq['throughput_rps']:.1f} req/s -> coalescing "
              f"{speed:.2f}x")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, default=str)
        print(f"[serve] payload written to {args.json}")
    return payload


if __name__ == "__main__":
    main()
