"""Distributed Poisson solve launcher (the paper's workload).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.solve --n 32 --p1 2 --p2 4 \
        --bcs unb --comm pipelined

Builds the pencil-decomposed solver on a (p1, p2) process grid, solves the
paper's fully-unbounded Gaussian-bump case and reports the error against
the analytical solution plus per-strategy timing.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--p1", type=int, default=1)
    ap.add_argument("--p2", type=int, default=1)
    ap.add_argument("--bcs", default="unb", choices=["unb", "per", "mix"])
    ap.add_argument("--layout", default="node", choices=["node", "cell"])
    ap.add_argument("--comm", default="a2a",
                    choices=["a2a", "pipelined", "fused", "overlap", "auto"])
    ap.add_argument("--chunks", type=int, default=2,
                    help="pipelined/overlap granularity (paper's n_batch)")
    ap.add_argument("--green", default="chat2")
    ap.add_argument("--engine", default="xla", choices=["xla", "pallas"],
                    help="transform engine: pure XLA or the Pallas kernels")
    ap.add_argument("--doubling", default="deferred",
                    choices=["deferred", "upfront"],
                    help="Hockney doubling: deferred (pruned transforms + "
                         "valid-extent switches, default) or upfront (dense "
                         "textbook baseline -- the bench_solve comparison)")
    ap.add_argument("--relayout", default="scheduled",
                    choices=["scheduled", "baseline"],
                    help="data-layout policy: scheduled (plan-time layout "
                         "schedule, relayouts folded into the topology "
                         "switches, default) or baseline (per-direction "
                         "moveaxis round trips -- the A/B reference)")
    ap.add_argument("--batch", type=int, default=1,
                    help="right-hand sides per solve (batched multi-RHS "
                         "pipeline when > 1)")
    ap.add_argument("--steps", type=int, default=1,
                    help="driver steps; each step re-acquires the solver "
                         "through the global plan cache (CFD-loop shape)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint directory; enables the survivable "
                         "--steps loop (periodic save, restart/resume, "
                         "elastic rebuild on injected device loss)")
    ap.add_argument("--ckpt-every", type=int, default=2,
                    help="checkpoint every k steps (with --ckpt)")
    ap.add_argument("--search", default="guided",
                    choices=["guided", "brute"],
                    help="comm=auto candidate policy: guided (cost-model "
                         "shortlist, times ~1/6 of the space) or brute "
                         "(exhaustive sweep -- the oracle reference)")
    ap.add_argument("--verify", default=None,
                    choices=["nan", "residual", "abft"],
                    help="opt-in per-solve health guard: nan/residual "
                         "(runtime.health) or abft (checksum-sandwiched "
                         "pipeline with localize-and-recompute, "
                         "runtime.abft / DESIGN.md #13)")
    args = ap.parse_args(argv)

    import os
    n_dev = args.p1 * args.p2
    if "XLA_FLAGS" not in os.environ:  # must precede the first jax import
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n_dev}"

    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.core.bc import BCType, DataLayout
    from repro.core.comm import CommConfig
    from repro.core.solver import get_solver, solver_cache_info

    E, O, P, U = BCType.EVEN, BCType.ODD, BCType.PER, BCType.UNB
    bcs = {"unb": ((U, U),) * 3,
           "per": ((P, P),) * 3,
           "mix": ((E, E), (O, E), (P, P))}[args.bcs]
    layout = DataLayout.NODE if args.layout == "node" else DataLayout.CELL

    n_dev = args.p1 * args.p2
    assert n_dev <= len(jax.devices()), (
        f"need {n_dev} devices; run with "
        f"XLA_FLAGS=--xla_force_host_platform_device_count={n_dev}")
    mesh = jax.make_mesh((args.p1, args.p2), ("data", "model"))
    comm = ("auto" if args.comm == "auto"
            else CommConfig(strategy=args.comm, n_chunks=args.chunks))
    solver = get_solver(
        (args.n,) * 3, 1.0, bcs, layout=layout, green_kind=args.green,
        mesh=mesh, comm=comm, dtype=jnp.float64,
        engine=args.engine, doubling=args.doubling,
        relayout=args.relayout, autotune_search=args.search)
    if args.comm == "auto":
        picked = (f"{solver.comm.strategy}"
                  f"(n_chunks={solver.comm.n_chunks})")
        cen = solver.autotune_census
        if args.search == "guided" and cen.get("shortlist") is not None:
            print(f"[solve] guided search: {cen['space']} candidates -> "
                  f"{len(cen['shortlist'])} timed "
                  f"({len(cen.get('pruned_padding', []))} pruned on "
                  "padding overhead)")
        if solver.autotune_results:
            print(f"[solve] comm=auto -> {picked}, candidates: " +
                  ", ".join(f"{k}={v*1e3:.1f}ms"
                            for k, v in sorted(
                                solver.autotune_results.items())))
        else:
            print(f"[solve] comm=auto -> {picked} (cached winner, "
                  "sweep skipped)")

    # rhs: the paper's validation field for the chosen BCs
    import sys
    sys.path.insert(0, "tests")
    from test_poisson import case_a, case_b
    rhs, sol = (case_b if args.bcs == "unb" else case_a)(args.n, layout)
    if args.bcs == "per":
        # simple periodic field
        h = 1.0 / args.n
        pts = (np.arange(args.n + (layout == DataLayout.NODE)) *
               h if layout == DataLayout.NODE
               else (np.arange(args.n) + 0.5) * h)
        x, y, z = np.meshgrid(pts, pts, pts, indexing="ij")
        sol = np.sin(2 * np.pi * x) * np.sin(4 * np.pi * y) * \
            np.cos(2 * np.pi * z)
        rhs = -(4 + 16 + 4) * np.pi ** 2 * sol

    if args.batch > 1:
        rhs = np.broadcast_to(rhs, (args.batch,) + rhs.shape).copy()

    if args.ckpt is not None:
        return _run_survivable(args, solver, mesh, comm, rhs, sol, bcs,
                               layout)

    u = solver.solve(rhs)          # compile + warm
    u.block_until_ready()
    t0 = time.perf_counter()
    for step in range(max(args.repeats, args.steps)):
        # CFD-driver shape: every step re-acquires the (cached) solver
        solver = get_solver(
            (args.n,) * 3, 1.0, bcs, layout=layout, green_kind=args.green,
            mesh=mesh, comm=comm, dtype=jnp.float64, engine=args.engine,
            doubling=args.doubling, relayout=args.relayout,
            autotune_search=args.search)
        u = solver.solve(rhs)
        u.block_until_ready()
    reps = max(args.repeats, args.steps)
    dt = (time.perf_counter() - t0) / reps
    u0 = np.asarray(u[0] if args.batch > 1 else u)
    err = float(np.max(np.abs(u0 - sol)))
    thr = rhs.size * 8 / dt / 1e6 / n_dev
    ci = solver_cache_info()
    print(f"[solve] n={args.n}^3 grid, ({args.p1}x{args.p2}) pencils, "
          f"comm={args.comm}, engine={args.engine}, batch={args.batch}: "
          f"{dt*1e3:.1f} ms/solve, E_inf={err:.3e}, "
          f"throughput {thr:.1f} MB/s/rank, "
          f"plan-cache {ci['hits']} hits / {ci['misses']} misses")
    return err


def _run_survivable(args, solver, mesh, comm, rhs, sol, bcs, layout):
    """The --ckpt variant of the --steps loop: a long-running CFD-style
    driver that checkpoints every ``--ckpt-every`` steps, restarts from the
    last valid step, and survives an injected device loss by rebuilding the
    solver on the shrunken surviving mesh (elastic recovery) and resuming
    from the last checkpoint.  Faults are armed via ``$REPRO_FAULTS``."""
    import contextlib
    import os

    import jax
    from jax.sharding import Mesh
    from repro.ckpt import checkpoint as ck
    from repro.runtime import faults

    plan = faults.plan_from_env()
    with (plan if plan is not None else contextlib.nullcontext()):
        # the driver state: an accumulated field (the stand-in for the
        # evolving CFD solution) -- what checkpoints must preserve
        acc = np.zeros(np.shape(rhs), dtype=np.float64)
        last = ck.latest_step(args.ckpt)
        step = 0
        if last is not None:
            acc = np.array(ck.restore(args.ckpt, last, acc),
                           dtype=np.float64)
            step = last + 1
            print(f"[solve] resuming from checkpoint step {last}")
        p1, p2 = args.p1, args.p2
        losses = 0
        while step < args.steps:
            if faults.should_fire("device_loss", step=step) and \
                    hasattr(solver, "rebuild"):
                # half the devices are gone: shrink to the survivors,
                # re-plan (Green + autotune cache reused), roll back to the
                # last checkpoint and resume there
                losses += 1
                if p1 > 1:
                    p1 //= 2
                elif p2 > 1:
                    p2 //= 2
                devs = np.array(jax.devices()[:p1 * p2]).reshape(p1, p2)
                mesh = Mesh(devs, mesh.axis_names)
                print(f"[solve] device loss at step {step}: rebuilding on "
                      f"({p1}x{p2}) surviving mesh")
                solver = solver.rebuild(mesh)
                last = ck.latest_step(args.ckpt)
                if last is None:
                    acc = np.zeros_like(acc)
                    step = 0
                else:
                    acc = np.array(ck.restore(args.ckpt, last, acc),
                           dtype=np.float64)
                    step = last + 1
                print(f"[solve] resumed at step {step}")
                continue
            # per-step rhs scaling: steps are distinguishable, so a resume
            # from the wrong step shows up in the final accumulated field
            u = solver.solve(rhs * (1.0 / (1 + step)), verify=args.verify)
            acc += np.asarray(u, dtype=np.float64)
            if (step + 1) % args.ckpt_every == 0:
                ck.save(args.ckpt, step, acc)
            step += 1

    scale = sum(1.0 / (1 + k) for k in range(args.steps))
    acc0 = acc[0] if args.batch > 1 else acc
    err = float(np.max(np.abs(acc0 / scale - sol)))
    stats = getattr(solver, "stats", {})
    ndeg = len(stats.get("degradations", ()))
    print(f"[solve] survivable loop: {args.steps} steps on final "
          f"({p1}x{p2}) mesh, {losses} device losses, "
          f"{ndeg} degradations, E_inf={err:.3e}")
    report_path = os.environ.get("REPRO_CHAOS_LOG")
    if report_path:
        # the CI chaos job uploads this as its artifact: what was injected,
        # what fired, what the ladder did about it, and the final error
        import json
        with open(report_path, "w") as fh:
            json.dump({"steps": args.steps, "final_mesh": [p1, p2],
                       "device_losses": losses, "err_inf": err,
                       "fault_log": plan.log if plan is not None else [],
                       "retries": stats.get("retries", 0),
                       "degradations": stats.get("degradations", []),
                       "integrity": stats.get("integrity", [])},
                      fh, indent=2)
        print(f"[solve] chaos report written to {report_path}")
    return err


if __name__ == "__main__":
    main()
