"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Production behaviours exercised here:
  * checkpoint every --ckpt-every steps (atomic, keep-last-k),
  * automatic resume from the latest step in --ckpt-dir,
  * fault injection (--fail-at N simulates a crash; relaunching resumes),
  * straggler detection: per-step wall time is tracked against a rolling
    median; outliers are logged with the step re-issued data-identically
    (the pipeline is stateless, see repro/data/pipeline.py),
  * optional int8 error-feedback gradient compression (--compress).
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.ckpt import checkpoint as ck
from repro.configs import get_config, get_smoke
from repro.core.comm import CommConfig
from repro.data.pipeline import synthetic_batch
from repro.training import optimizer as opt
from repro.training.train_step import make_train_state, train_step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a crash after this step")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--comm", default="a2a")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    adam = opt.AdamWConfig(
        lr=args.lr, total_steps=args.steps,
        warmup=min(20, args.steps // 10 + 1),
        grad_compress="int8" if args.compress else "none")
    comm = CommConfig(strategy=args.comm)

    state = make_train_state(jax.random.PRNGKey(0), cfg, adam=adam)
    start = 0
    if args.ckpt_dir:
        latest = ck.latest_step(args.ckpt_dir)
        if latest is not None:
            like = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
            state = ck.restore(args.ckpt_dir, latest, like)
            start = latest
            print(f"[train] resumed from step {latest}")

    step_fn = jax.jit(train_step_fn(cfg, adam=adam, comm=comm))
    times = []
    for step in range(start, args.steps):
        t0 = time.perf_counter()
        batch = synthetic_batch(cfg, step, args.batch, args.seq)
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        times.append(dt)
        med = float(np.median(times[-20:]))
        if len(times) > 5 and dt > 3.0 * med:
            print(f"[train] STRAGGLER step {step}: {dt:.2f}s vs median "
                  f"{med:.2f}s (stateless pipeline -> safe to re-issue)")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step}  loss {float(metrics['loss']):.4f}"
                  f"  gnorm {float(metrics['grad_norm']):.3f}"
                  f"  lr {float(metrics['lr']):.2e}  {dt:.2f}s")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ck.save(args.ckpt_dir, step + 1, state)
        if args.fail_at is not None and step + 1 >= args.fail_at:
            raise SystemExit(f"[train] simulated failure at step {step + 1}"
                             " -- relaunch to resume")
    print("[train] done")
    return state


if __name__ == "__main__":
    main()
