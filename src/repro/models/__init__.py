from .common import ModelConfig, MoEConfig, SSMConfig, HybridConfig
from .transformer import init_params, forward, param_specs
