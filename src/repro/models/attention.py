"""GQA/MQA attention with RoPE, qk-norm, sliding windows, KV caches.

The sequence-parallel variant routes its head/sequence transposes through
the flups transpose engine (``repro.core.comm.topology_switch``) -- the
paper's pencil topology switch applied to attention (Ulysses-style).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import (ModelConfig, apply_rope, dense_init, norm_params,
                     rms_norm, rope_freqs)

NEG_INF = -2.0e38
_LSE_MIN = -1.0e30


def init_attn(key, cfg: ModelConfig, d_model=None):
    d = d_model or cfg.d_model
    dh, h, hkv = cfg.d_head, cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, dh), cfg.pdtype(), fan_in=d),
        "wk": dense_init(ks[1], (d, hkv, dh), cfg.pdtype(), fan_in=d),
        "wv": dense_init(ks[2], (d, hkv, dh), cfg.pdtype(), fan_in=d),
        "wo": dense_init(ks[3], (h, dh, d), cfg.pdtype(), fan_in=h * dh),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_params(cfg, dh)
        p["k_norm"] = norm_params(cfg, dh)
    return p


def _mask(cfg: ModelConfig, q_pos, k_pos, causal):
    """(..., Sq, Sk) additive mask."""
    m = jnp.zeros(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]),
                  jnp.float32)
    if causal:
        m = jnp.where(k_pos[..., None, :] > q_pos[..., :, None], NEG_INF, m)
    if cfg.window:
        m = jnp.where(k_pos[..., None, :] <= q_pos[..., :, None] - cfg.window,
                      NEG_INF, m)
    return m


def _qkv(p, cfg: ModelConfig, x, positions, rope=True):
    cd = cfg.cdtype()
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    if rope:
        cos, sin = rope_freqs(cfg, positions)
        q = apply_rope(q, cos, sin, cfg.rope_fraction)
        k = apply_rope(k, cos, sin, cfg.rope_fraction)
    return q, k, v


def _sdpa(cfg: ModelConfig, q, k, v, mask):
    """q: (b,sq,h,dh), k/v: (b,sk,hkv,dh) -> (b,sq,h,dh)."""
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg, k).astype(jnp.float32)
    logits = logits / np.sqrt(dh) + mask[:, None, None]
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqs,bshk->bqhgk", w, v)
    return o.reshape(b, sq, h, dh)


def _sdpa_chunked(cfg: ModelConfig, q, k, v, q_pos, k_pos, causal,
                  prefix_len=0):
    """Exact chunked attention: python loop over static q blocks, each
    attending a static KV slice (causal upper bound / sliding window).

    Peak memory is one (b, h, qb, kv_extent) logits block instead of the
    full S x S square, and the causal triangle above each block is never
    computed (roughly 2x fewer attention FLOPs at long S).
    """
    b, s, h, dh = q.shape
    qb = min(cfg.attn_block, s)
    n_blocks = -(-s // qb)
    outs = []
    for i in range(n_blocks):
        lo, hi = i * qb, min((i + 1) * qb, s)
        # static KV extent: causal -> [0, hi); window -> last (win + qb)
        k_lo = 0
        if cfg.window:
            k_lo = max(0, hi - cfg.window - qb)
        k_hi = hi if causal else s
        qs = q[:, lo:hi]
        ks = k[:, k_lo:k_hi]
        vs = v[:, k_lo:k_hi]
        mask = _mask(cfg, q_pos[:, lo:hi], k_pos[:, k_lo:k_hi], causal)
        if prefix_len:
            kp = k_pos[:, k_lo:k_hi][..., None, :]
            mask = jnp.where(kp < prefix_len, 0.0, mask)
        outs.append(_sdpa(cfg, qs, ks, vs, mask))
    return jnp.concatenate(outs, axis=1)


def attention(p, cfg: ModelConfig, x, positions, causal=True, rope=True,
              prefix_len=0, return_kv=False):
    """Full (training / prefill) attention. x: (B, S, D)."""
    q, k, v = _qkv(p, cfg, x, positions, rope)
    if cfg.attn_block and x.shape[1] > cfg.attn_block:
        o = _sdpa_chunked(cfg, q, k, v, positions, positions, causal,
                          prefix_len)
    else:
        mask = _mask(cfg, positions, positions, causal)
        if prefix_len:
            kp = positions[..., None, :]
            mask = jnp.where(kp < prefix_len, 0.0, mask)
        o = _sdpa(cfg, q, k, v, mask)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cfg.cdtype()))
    return (out, (k, v)) if return_kv else out


def attention_ring(p, cfg: ModelConfig, x, mesh, causal=True, rope=True,
                   prefix_len=0):
    """Ring attention over the "model" mesh axis (sequence-sharded KV).

    The sequence is sharded over the model axis; each rank computes its
    queries against its local KV block, then the KV blocks rotate around
    the ring (collective-permute), with an online-softmax accumulation.
    This is the paper's pipelined topology switch applied to attention:
    P-1 point-to-point steps instead of one big collective, each step's
    compute overlapping the next block's transfer -- and the rank->rank+1
    rotation is exactly the congestion-avoiding send ordering of
    Appendix A.1.  Works for ANY head count (no head-divisibility
    constraint), so it is the TP strategy for e.g. 36-head starcoder2 on a
    16-wide model axis.

    For sliding-window configs only ceil(window/S_loc)+1 ring steps carry
    any unmasked work; the rest are statically skipped.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from .common import DATA_AXES

    n_ring = mesh.shape["model"]
    dp = tuple(a for a in DATA_AXES if a in mesh.shape)
    b, s, d = x.shape
    s_loc = s // n_ring
    if cfg.window:
        n_steps = min(n_ring, -(-cfg.window // s_loc) + 1)
    else:
        n_steps = n_ring
    perm = [(i, (i + 1) % n_ring) for i in range(n_ring)]

    def body(xloc, wq, wk, wv, wo, qn, kn):
        r = jax.lax.axis_index("model")
        pos_q = r * s_loc + jnp.arange(s_loc)           # (s_loc,)
        posb = jnp.broadcast_to(pos_q, xloc.shape[:1] + (s_loc,))
        pp = {"wq": wq, "wk": wk, "wv": wv}
        if cfg.qk_norm:
            pp["q_norm"], pp["k_norm"] = qn, kn
        q, k, v = _qkv(pp, cfg, xloc, posb, rope)
        bl, _, h, dh = q.shape
        hkv = k.shape[2]
        g = h // hkv
        qg = q.reshape(bl, s_loc, hkv, g, dh)

        acc = jnp.zeros((bl, hkv, g, s_loc, dh), jnp.float32)
        mx = jnp.full((bl, hkv, g, s_loc), -jnp.inf, jnp.float32)
        li = jnp.zeros((bl, hkv, g, s_loc), jnp.float32)
        kv = (k, v)
        for t in range(n_steps):
            owner = (r - t) % n_ring
            pos_k = owner * s_loc + jnp.arange(s_loc)
            kt, vt = kv
            logits = jnp.einsum("bqhgk,bshk->bhgqs", qg,
                                kt).astype(jnp.float32) / np.sqrt(dh)
            mask = jnp.zeros((s_loc, s_loc), jnp.float32)
            if causal:
                mask = jnp.where(pos_k[None, :] > pos_q[:, None], NEG_INF,
                                 mask)
            if cfg.window:
                mask = jnp.where(pos_k[None, :] <= pos_q[:, None]
                                 - cfg.window, NEG_INF, mask)
            if prefix_len:
                mask = jnp.where(pos_k[None, :] < prefix_len, 0.0, mask)
            logits = logits + mask[None, None, None]
            bmx = jnp.maximum(mx, logits.max(axis=-1))
            bmx_safe = jnp.maximum(bmx, _LSE_MIN)
            scale = jnp.exp(jnp.maximum(mx, _LSE_MIN) - bmx_safe)
            w = jnp.exp(logits - bmx_safe[..., None])
            li = li * scale + w.sum(axis=-1)
            acc = acc * scale[..., None] + jnp.einsum(
                "bhgqs,bshk->bhgqk", w, vt.astype(jnp.float32))
            mx = bmx
            if t < n_steps - 1:
                kv = jax.lax.ppermute(kv, "model", perm)
        out = acc / jnp.maximum(li[..., None], 1e-30)
        out = out.transpose(0, 3, 1, 2, 4).reshape(bl, s_loc, h, dh)
        return jnp.einsum("bshk,hkd->bsd", out.astype(xloc.dtype),
                          wo.astype(cfg.cdtype()))

    wspec = (P(None, None, None),) * 4
    nspec = (P(None) if False else {"scale": P(None)}) if cfg.qk_norm \
        else None
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, "model", None),) + wspec + (nspec, nspec),
        out_specs=P(dp, "model", None),
        check_vma=False)
    return fn(x, p["wq"], p["wk"], p["wv"], p["wo"],
              p.get("q_norm"), p.get("k_norm"))


def attention_cross(p, cfg: ModelConfig, x, kv_cache):
    """Cross-attention against precomputed encoder K/V (whisper decoder)."""
    cd = cfg.cdtype()
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
    k, v = kv_cache
    b, sq = q.shape[:2]
    mask = jnp.zeros((b, sq, k.shape[1]), jnp.float32)
    o = _sdpa(cfg, q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cd))


def encode_kv(p, cfg: ModelConfig, x_enc):
    cd = cfg.cdtype()
    k = jnp.einsum("bsd,dhk->bshk", x_enc, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x_enc, p["wv"].astype(cd))
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    return k, v


# -- decode path -------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch, max_len, dtype):
    """KV cache for one attention layer: (B, S_max, Hkv, dh) pair."""
    shape = (batch, max_len, cfg.n_kv, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(p, cfg: ModelConfig, x, cache, pos):
    """One-token decode.  x: (B, 1, D); pos: scalar int (current index).

    Returns (out, new_cache).  For sliding-window configs the cache is a
    rolling buffer of size ``cfg.window``.
    """
    q, k, v = _qkv(p, cfg, x, jnp.full((x.shape[0], 1), pos), rope=True)
    s_max = cache["k"].shape[1]
    if cfg.window and s_max == cfg.window:
        slot = pos % cfg.window
    else:
        slot = pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    idx = jnp.arange(s_max)
    if cfg.window and s_max == cfg.window:
        # rolling buffer: entry i holds absolute position matching slot order
        age = (slot - idx) % cfg.window
        kpos = pos - age
        valid = kpos >= 0
    else:
        kpos = idx
        valid = idx <= pos
    mask = jnp.where(valid, 0.0, NEG_INF)[None, None, :]
    mask = jnp.broadcast_to(mask, (x.shape[0], 1, s_max)).astype(jnp.float32)
    o = _sdpa(cfg, q, ck.astype(q.dtype), cv.astype(q.dtype), mask)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cfg.cdtype()))
    return out, {"k": ck, "v": cv}
