"""Shared model components: configs, norms, rope, initializers, sharding.

Everything is pure JAX (params = nested dicts of jnp arrays).  Dtypes are
explicit throughout: ``param_dtype`` for storage (f32 master), and
``compute_dtype`` (bf16) applied on entry to each block.

Sharding is expressed as a tree of ``PartitionSpec`` parallel to the param
tree (see ``transformer.param_specs``), using logical mesh axis names:
``data`` axes shard the batch, ``model`` shards heads / ffn / experts /
vocab (tensor / expert parallelism).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

DATA_AXES = ("pod", "data")  # batch shards over these when present


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style: pattern of (rec, rec, attn) blocks."""
    d_rnn: int = 0               # lru width (0 -> d_model)
    conv_width: int = 4
    window: int = 2048           # local attention window
    pattern: tuple = ("rec", "rec", "attn")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 128
    act: str = "swiglu"          # swiglu | geglu | gelu | relu2
    norm: str = "rms"            # rms | layer
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_fraction: float = 1.0
    tie_embeddings: bool = False
    scale_embed: bool = False    # gemma-style sqrt(d) embedding scale
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    n_enc_layers: int = 0        # encoder layers (whisper)
    n_frontend_tokens: int = 0   # stub modality tokens (audio frames/patches)
    window: int = 0              # sliding-window attention (0 = full)
    norm_eps: float = 1e-6
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "block"         # none | block | full
    scan_layers: bool = True     # False: python-unrolled (flops probes)
    unroll_inner: bool = False   # unroll inner (chunk) scans (flops probes)
    attn_block: int = 0          # chunked attention q-block (0 = naive)
    attn_ring: bool = False      # ring attention over the model axis
    mlp_weight_gathered: bool = False  # replicate MLP over model axis and
    # keep activations sequence-sharded (wins when S_loc*B_loc*d > |W|)
    seq_parallel: bool = True    # sequence-shard the residual stream

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def n_params(self) -> int:
        """Approximate parameter count (reported in configs/tests)."""
        leaves = jax.eval_shape(
            lambda: __import__("repro.models.transformer",
                               fromlist=["init_params"]).init_params(
                                   jax.random.PRNGKey(0), self))
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(leaves))


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------

def maybe_constrain(x, *spec):
    """with_sharding_constraint iff an abstract mesh with these axes is
    active (set via ``jax.sharding.use_mesh`` in the launch layer); no-op in
    single-device smoke tests."""
    from jax.sharding import PartitionSpec as P
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.shape:
        return x
    names = set(mesh.axis_names)

    def ok(s):
        if s is None:
            return True
        if isinstance(s, (tuple, list)):
            return all(a in names for a in s)
        return s in names

    if not all(ok(s) for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def batch_spec(mesh_names):
    """The data-parallel sharding tuple for the batch dimension."""
    return tuple(a for a in DATA_AXES if a in mesh_names)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, w, b, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rms":
        return rms_norm(x, p["scale"], cfg.norm_eps)
    return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)


def norm_params(cfg: ModelConfig, d):
    if cfg.norm == "rms":
        return {"scale": jnp.zeros((d,), cfg.pdtype())}
    return {"scale": jnp.ones((d,), cfg.pdtype()),
            "bias": jnp.zeros((d,), cfg.pdtype())}


def act_fn(name: str, x, gate=None):
    if name == "swiglu":
        return jax.nn.silu(gate) * x
    if name == "geglu":
        return jax.nn.gelu(gate, approximate=True) * x
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def is_gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


def rope_freqs(cfg: ModelConfig, positions):
    """positions: int array (...,) -> (cos, sin) of shape (..., rot/2)."""
    rot = int(cfg.d_head * cfg.rope_fraction)
    inv = 1.0 / (cfg.rope_theta ** (np.arange(0, rot, 2) / rot))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, rope_fraction=1.0):
    """x: (..., S, n_heads, d_head); cos/sin: (..., S, rot/2)."""
    dh = x.shape[-1]
    rot = cos.shape[-1] * 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    # broadcast over the heads axis: x is (..., S, H, dh); cos is (..., S, r/2)
    c = cos[..., None, :]
    s = sin[..., None, :]
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    out = jnp.concatenate([y1, y2], axis=-1)
    if rot < dh:
        out = jnp.concatenate([out, xp], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n, d):
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * 0.02).astype(dtype)
