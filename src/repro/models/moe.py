"""Expert-parallel MoE layer with flups-style all-to-all dispatch.

The token -> expert exchange is a pencil topology switch: tokens are
sequence-sharded over the ``model`` mesh axis, experts are expert-sharded
over the same axis, and dispatch/combine each perform exactly one
``topology_switch`` (paper section III) scoped to that axis -- selectable
strategy (a2a / pipelined / fused) like every other switch in the system.

Dispatch is capacity-based (GShard-style, capacity_factor configurable);
overflow drops are counted and returned as an aux metric.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.comm import CommConfig, topology_switch
from .common import ModelConfig, dense_init, act_fn, is_gated, DATA_AXES, \
    maybe_constrain


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d, dff, e = cfg.d_model, cfg.d_ff, m.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32, fan_in=d),
        "w_in": dense_init(ks[1], (e, d, dff), cfg.pdtype(), fan_in=d),
        "w_out": dense_init(ks[2], (e, dff, d), cfg.pdtype(), fan_in=dff),
    }
    if is_gated(cfg.act):
        p["w_gate"] = dense_init(ks[3], (e, d, dff), cfg.pdtype(), fan_in=d)
    return p


def _route(p, m, xf):
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, m.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return gate, idx


def _dispatch_local(x, idx, n_experts, capacity):
    """Bucket local tokens into a (E, C, d) buffer.

    x: (T, d); idx: (T, k) top-k expert assignments.
    Returns buf (E, C, d) and the (dest, keep) bookkeeping for combine.
    """
    t, k = idx.shape
    flat_e = idx.reshape(-1)                      # (T*k,)
    # position of each entry within its expert's bucket
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                          # (T*k, E)
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = slot < capacity
    slot_c = jnp.where(keep, slot, 0)
    dest = flat_e * capacity + slot_c             # flat (E*C) index
    src = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((n_experts * capacity, x.shape[-1]), x.dtype)
    buf = buf.at[dest].add(jnp.where(keep[:, None], x[src], 0.0))
    return buf.reshape(n_experts, capacity, -1), (dest, keep)


def _combine_local(ybuf, book, gate, t, k):
    dest, keep = book
    y = ybuf.reshape(-1, ybuf.shape[-1])[dest]    # (T*k, d)
    y = jnp.where(keep[:, None], y, 0.0)
    y = y * gate.reshape(-1)[:, None].astype(y.dtype)
    return y.reshape(t, k, -1).sum(axis=1)


def _expert_ffn(cfg, buf, w_in, w_gate, w_out):
    cd = cfg.cdtype()
    h = jnp.einsum("ecd,edf->ecf", buf, w_in.astype(cd))
    if w_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(cd))
        h = act_fn(cfg.act, h, g)
    else:
        h = act_fn(cfg.act, h)
    return jnp.einsum("ecf,efd->ecd", h, w_out.astype(cd))


def _moe_shard(x, router, w_in, w_gate, w_out, *, cfg: ModelConfig,
               comm: CommConfig, axes: tuple):
    """Per-shard body (inside shard_map; experts sharded over 'model')."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    gate, idx = _route({"router": router}, m, xf)
    capacity = int(t * m.top_k / m.n_experts * m.capacity_factor) + 1
    buf, book = _dispatch_local(xf, idx, m.n_experts, capacity)

    # flups topology switch #1: (E, C, d) -> (E_loc, C * n_shards, d)
    buf = topology_switch(buf, "model", 0, 1, comm)
    y = _expert_ffn(cfg, buf, w_in, w_gate, w_out)
    # flups topology switch #2 (reverse): back to the token layout
    y = topology_switch(y, "model", 1, 0, comm)

    out = _combine_local(y, book, gate, t, m.top_k)
    drop = jax.lax.pmean(1.0 - book[1].mean(), axes)
    return out.reshape(b, s, d).astype(x.dtype), drop


def moe_block(p, cfg: ModelConfig, x, comm: CommConfig, mesh=None):
    """MoE FFN. x: (B, S, D); S is sharded over the model axis inside
    (sequence-parallel region).  Falls back to single-shard execution when
    no mesh is given (CPU smoke tests)."""
    if mesh is None or "model" not in mesh.shape:
        return _moe_local(p, cfg, x)
    dp = tuple(a for a in DATA_AXES if a in mesh.shape)
    axes = tuple(mesh.axis_names)
    w_gate_spec = P("model", None, None) if "w_gate" in p else None
    specs_in = (P(dp, "model", None), P(None, None),
                P("model", None, None), w_gate_spec, P("model", None, None))
    fn = jax.shard_map(
        partial(_moe_shard, cfg=cfg, comm=comm, axes=axes),
        mesh=mesh,
        in_specs=specs_in,
        out_specs=(P(dp, "model", None), P()),
        check_vma=False,
    )
    return fn(x, p["router"], p["w_in"], p.get("w_gate"), p["w_out"])


def _moe_local(p, cfg: ModelConfig, x):
    """Single-device / decode fallback: identical math, no manual
    collectives; expert tensors stay shardable via constraints."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    gate, idx = _route(p, m, xf)
    capacity = int(t * m.top_k / m.n_experts * m.capacity_factor) + 1
    buf, book = _dispatch_local(xf, idx, m.n_experts, capacity)
    buf = maybe_constrain(buf, "model", None, None)
    y = _expert_ffn(cfg, buf, p["w_in"], p.get("w_gate"), p["w_out"])
    y = maybe_constrain(y, "model", None, None)
    out = _combine_local(y, book, gate, t, m.top_k)
    drop = 1.0 - book[1].mean()
    return out.reshape(b, s, d).astype(x.dtype), drop
