"""RG-LRU recurrent block (RecurrentGemma / Griffin).

    r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)

computed with an associative scan over the sequence.  The block wraps the
LRU with the Griffin recurrent-block structure: linear in (x2 branches),
short causal conv, LRU, gated linear out.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init

_C = 8.0


def init_rglru(key, cfg: ModelConfig):
    d = cfg.d_model
    dr = cfg.hybrid.d_rnn or d
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], (d, dr), cfg.pdtype(), fan_in=d),
        "w_y": dense_init(ks[1], (d, dr), cfg.pdtype(), fan_in=d),
        "conv_w": dense_init(ks[2], (cfg.hybrid.conv_width, dr),
                             cfg.pdtype(), fan_in=cfg.hybrid.conv_width),
        "conv_b": jnp.zeros((dr,), cfg.pdtype()),
        "w_r": dense_init(ks[3], (dr, dr), cfg.pdtype(), fan_in=dr),
        "w_i": dense_init(ks[4], (dr, dr), cfg.pdtype(), fan_in=dr),
        # Lambda init so a^(1/c) ~ U(0.9, 0.999) (griffin appendix)
        "lam": jnp.asarray(
            np.log(np.expm1(-np.log(np.linspace(0.9, 0.999, dr)))),
            cfg.pdtype()),
        "w_out": dense_init(ks[5], (dr, d), cfg.pdtype(), fan_in=dr),
    }


def _lru_coeffs(p, cfg, u):
    """u: (B, S, dr) -> per-step decay a and input b = sqrt(1-a^2)*i*u."""
    r = jax.nn.sigmoid(u @ p["w_r"].astype(u.dtype))
    i = jax.nn.sigmoid(u @ p["w_i"].astype(u.dtype))
    lam = jax.nn.softplus(p["lam"].astype(jnp.float32))
    log_a = (-_C * lam) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * u).astype(jnp.float32)
    return a, b


def _conv(u, w, b, width):
    pad = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * w[i] for i in range(width))
    return out + b


def rglru_block(p, cfg: ModelConfig, x, return_tail=False):
    """x: (B, S, D) -> (out, final_state (B, dr), conv_tail)."""
    cd = cfg.cdtype()
    u_raw = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(cd))
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_y"].astype(cd)),
                       approximate=True)
    conv_tail = (u_raw[:, -(cfg.hybrid.conv_width - 1):, :]
                 if return_tail else None)
    u = _conv(u_raw, p["conv_w"].astype(cd), p["conv_b"].astype(cd),
              cfg.hybrid.conv_width)
    a, bb = _lru_coeffs(p, cfg, u)

    # associative scan: (a2, b2) o (a1, b1) = (a1*a2, a2*b1 + b2)
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, bb), axis=1)
    h = hh.astype(cd)
    out = jnp.einsum("bse,ed->bsd", h * gate, p["w_out"].astype(cd))
    return out, hh[:, -1].astype(jnp.float32), conv_tail


def init_rglru_cache(cfg: ModelConfig, batch, dtype):
    dr = cfg.hybrid.d_rnn or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.hybrid.conv_width - 1, dr), dtype),
        "state": jnp.zeros((batch, dr), jnp.float32),
    }


def rglru_decode(p, cfg: ModelConfig, x, cache):
    """One token. x: (B, 1, D)."""
    cd = cfg.cdtype()
    u = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(cd))
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_y"].astype(cd)),
                       approximate=True)
    hist = jnp.concatenate([cache["conv"], u], axis=1)
    w = p["conv_w"].astype(cd)
    conv = sum(hist[:, i, :] * w[i] for i in range(cfg.hybrid.conv_width))
    u1 = (conv + p["conv_b"].astype(cd))[:, None, :]
    a, bb = _lru_coeffs(p, cfg, u1)
    h = cache["state"] * a[:, 0] + bb[:, 0]
    out = jnp.einsum("be,ed->bd", h.astype(cd) * gate[:, 0],
                     p["w_out"].astype(cd))[:, None, :]
    return out, {"conv": hist[:, 1:], "state": h}
