"""Mamba-2 (SSD, state-space duality) block, chunked scan + O(1) decode.

The SSD form (Dao & Gu 2024): per head, scalar-decay SSM
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t,   y_t = C_t^T h_t + D x_t
computed chunk-parallel: quadratic attention-like term inside chunks of
length ``chunk`` + a sequential (scan) state pass between chunks.  The
inter-chunk pass is the paper-analogue of the pipelined topology switch:
chunk k's intra work overlaps chunk k+1's state dependency.

Decode carries (conv_state, ssm_state) and costs O(1) per token.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, norm_params, rms_norm


def init_ssm(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    nh = s.n_heads(d)
    ks = jax.random.split(key, 6)
    conv_dim = din + 2 * s.d_state
    p = {
        # fused input projection: [z (gate), x, B, C, dt]
        "w_in": dense_init(ks[0], (d, 2 * din + 2 * s.d_state + nh),
                           cfg.pdtype(), fan_in=d),
        "conv_w": dense_init(ks[1], (s.d_conv, conv_dim), cfg.pdtype(),
                             fan_in=s.d_conv),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdtype()),
        "a_log": jnp.log(jnp.linspace(1.0, float(nh), nh)).astype(
            cfg.pdtype()),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.linspace(1e-3, 0.1, nh))), cfg.pdtype()),
        "d_skip": jnp.ones((nh,), cfg.pdtype()),
        "out_norm": norm_params(cfg, din),
        "w_out": dense_init(ks[2], (din, d), cfg.pdtype(), fan_in=din),
    }
    return p


def _split_proj(cfg, proj):
    s = cfg.ssm
    din = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    z, xbc_dt = jnp.split(proj, [din], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [din + 2 * s.d_state], axis=-1)
    return z, xbc, dt


def _conv1d(xbc, w, b, d_conv):
    """Causal depthwise conv along the sequence. xbc: (B, S, C)."""
    pad = jnp.pad(xbc, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(d_conv))
    return jax.nn.silu(out + b)


def ssd_chunked(xh, dt, a, B, C, chunk, unroll=False):
    """SSD scan.  xh: (b, s, h, p); dt: (b, s, h); B,C: (b, s, n).

    One ``lax.scan`` over chunks: each step does the quadratic intra-chunk
    work AND consumes/produces the inter-chunk state, so peak memory is one
    chunk's (q, k, h) block and the state dependency is the only sequential
    edge (the schedule the paper's ``nb`` strategy exposes to MPI).

    Returns y (b, s, h, p) and the final state (b, h, p, n).
    """
    b, s, h, pdim = xh.shape
    n = B.shape[-1]
    nc = s // chunk
    la = dt * a                                      # log-decay per step < 0
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(h_in, inp):
        xc, dtc, lac, Bc, Cc = inp                   # (b,c,...) one chunk
        seg = jnp.cumsum(lac, axis=1)                # (b,c,h)
        decay = seg[:, :, None, :] - seg[:, None, :, :]      # (b,q,k,h)
        w = jnp.where(causal[None, :, :, None], jnp.exp(decay), 0.0)
        scores = jnp.einsum("bqn,bkn->bqk", Cc, Bc)
        y = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, w * dtc[:, None], xc)
        # contribution of the incoming state
        y = y + jnp.einsum("bqn,bqh,bhpn->bqhp", Cc, jnp.exp(seg), h_in)
        # outgoing state
        tail = seg[:, -1:, :] - seg
        out_state = jnp.einsum("bkh,bkn,bkhp->bhpn",
                               jnp.exp(tail) * dtc, Bc, xc)
        h_out = h_in * jnp.exp(seg[:, -1])[..., None, None] + out_state
        return h_out, y

    def rs(v):  # (b, s, ...) -> (nc, b, chunk, ...)
        return v.reshape((b, nc, chunk) + v.shape[2:]).swapaxes(0, 1)

    init = jnp.zeros((b, h, pdim, n), xh.dtype)
    final, ys = jax.lax.scan(
        step, init, (rs(xh), rs(dt), rs(la), rs(B), rs(C)),
        unroll=nc if unroll else 1)
    y = ys.swapaxes(0, 1).reshape(b, s, h, pdim)
    return y, final


def ssm_block(p, cfg: ModelConfig, x, return_tail=False):
    """Training / prefill forward. x: (B, S, D).

    Returns (out, final_state, conv_tail); conv_tail is the raw xbc history
    needed to continue decoding (None unless ``return_tail``)."""
    s = cfg.ssm
    cd = cfg.cdtype()
    din = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(cd))
    z, xbc_raw, dt = _split_proj(cfg, proj)
    xbc = xbc_raw
    conv_tail = xbc_raw[:, -(s.d_conv - 1):, :] if return_tail else None
    xbc = _conv1d(xbc, p["conv_w"].astype(cd), p["conv_b"].astype(cd),
                  s.d_conv)
    xs, B, C = jnp.split(xbc, [din, din + s.d_state], axis=-1)
    bsz, slen = x.shape[:2]
    xh = xs.reshape(bsz, slen, nh, s.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, state = ssd_chunked(xh.astype(jnp.float32), dt, a,
                           B.astype(jnp.float32), C.astype(jnp.float32),
                           min(s.chunk, slen), unroll=cfg.unroll_inner)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[
        None, None, :, None]
    y = y.reshape(bsz, slen, din).astype(cd)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"]["scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cd))
    return out, state, conv_tail


# -- decode -------------------------------------------------------------------

def init_ssm_cache(cfg: ModelConfig, batch, dtype):
    s = cfg.ssm
    din = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = din + 2 * s.d_state
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }


def ssm_decode(p, cfg: ModelConfig, x, cache):
    """One token. x: (B, 1, D)."""
    s = cfg.ssm
    cd = cfg.cdtype()
    din = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(cd))
    z, xbc, dt = _split_proj(cfg, proj)
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)   # (B, d_conv, C)
    w = p["conv_w"].astype(cd)
    conv = sum(hist[:, i, :] * w[i] for i in range(s.d_conv))
    xbc1 = jax.nn.silu(conv + p["conv_b"].astype(cd))[:, None, :]
    xs, B, C = jnp.split(xbc1, [din, din + s.d_state], axis=-1)
    xh = xs.reshape(-1, nh, s.head_dim).astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) +
                          p["dt_bias"].astype(jnp.float32))     # (B, h)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dec = jnp.exp(dtv * a)                                       # (B, h)
    Bv = B[:, 0].astype(jnp.float32)                             # (B, n)
    Cv = C[:, 0].astype(jnp.float32)
    st = cache["state"] * dec[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dtv, Bv, xh)
    y = jnp.einsum("bn,bhpn->bhp", Cv, st)
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(-1, 1, din).astype(cd)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"]["scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cd))
    return out, {"conv": hist[:, 1:, :], "state": st}
