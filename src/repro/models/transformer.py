"""Model assembly for all assigned architectures.

One parameter tree + three entry points per architecture family:

  * ``forward``      -- training / scoring (full sequence, causal or prefix)
  * ``prefill``      -- forward + build decode caches (serving, prompt pass)
  * ``decode_step``  -- one token with caches (serving, autoregressive)

Uniform stacks are ``lax.scan``-ned over stacked layer params (compact HLO,
fast compiles at 94 layers); the hybrid (RecurrentGemma) stack scans over
its (rec, rec, attn) pattern groups.  ``param_specs`` produces the
PartitionSpec tree (TP/EP over "model", DP over "pod"/"data").
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.comm import CommConfig
from . import attention as attn
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .common import (ModelConfig, dense_init, embed_init, norm, norm_params,
                     act_fn, is_gated, maybe_constrain, sinusoidal_positions)


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _mesh_axis(mesh, name):
    try:
        return mesh.shape[name] if mesh is not None else 1
    except Exception:
        return 1


def _init_mlp(key, cfg: ModelConfig, d=None, dff=None):
    d = d or cfg.d_model
    dff = dff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], (d, dff), cfg.pdtype(), fan_in=d),
         "w_out": dense_init(ks[1], (dff, d), cfg.pdtype(), fan_in=dff)}
    if is_gated(cfg.act):
        p["w_gate"] = dense_init(ks[2], (d, dff), cfg.pdtype(), fan_in=d)
    return p


def _init_block(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"ln1": norm_params(cfg, cfg.d_model),
                "ssm": ssm_mod.init_ssm(ks[0], cfg)}
    if kind == "rec":
        return {"ln1": norm_params(cfg, cfg.d_model),
                "rec": rglru_mod.init_rglru(ks[0], cfg),
                "ln2": norm_params(cfg, cfg.d_model),
                "mlp": _init_mlp(ks[1], cfg)}
    if kind == "moe":
        return {"ln1": norm_params(cfg, cfg.d_model),
                "attn": attn.init_attn(ks[0], cfg),
                "ln2": norm_params(cfg, cfg.d_model),
                "moe": moe_mod.init_moe(ks[1], cfg)}
    if kind == "cross":  # whisper decoder block
        return {"ln1": norm_params(cfg, cfg.d_model),
                "attn": attn.init_attn(ks[0], cfg),
                "lnx": norm_params(cfg, cfg.d_model),
                "xattn": attn.init_attn(ks[1], cfg),
                "ln2": norm_params(cfg, cfg.d_model),
                "mlp": _init_mlp(ks[2], cfg)}
    # dense attention block
    return {"ln1": norm_params(cfg, cfg.d_model),
            "attn": attn.init_attn(ks[0], cfg),
            "ln2": norm_params(cfg, cfg.d_model),
            "mlp": _init_mlp(ks[1], cfg)}


def _stack_init(key, cfg, kind, n):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_block(k, cfg, kind))(keys)


def _hybrid_layout(cfg: ModelConfig):
    """(n_groups, remainder_kinds) for the hybrid pattern."""
    pat = cfg.hybrid.pattern
    n_groups = cfg.n_layers // len(pat)
    rem = cfg.n_layers - n_groups * len(pat)
    return n_groups, tuple(pat[:rem])


def init_params(key, cfg: ModelConfig):
    k_embed, k_stack, k_out, k_enc = jax.random.split(key, 4)
    p = {"embed": embed_init(k_embed, (cfg.vocab, cfg.d_model),
                             cfg.pdtype()),
         "ln_f": norm_params(cfg, cfg.d_model)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k_out, (cfg.d_model, cfg.vocab),
                                  cfg.pdtype(), fan_in=cfg.d_model)
    if cfg.family == "ssm":
        p["layers"] = _stack_init(k_stack, cfg, "ssm", cfg.n_layers)
    elif cfg.family == "moe":
        p["layers"] = _stack_init(k_stack, cfg, "moe", cfg.n_layers)
    elif cfg.family == "hybrid":
        n_groups, rem = _hybrid_layout(cfg)
        pat = cfg.hybrid.pattern
        p["groups"] = {
            kind + str(i): _stack_init(jax.random.fold_in(k_stack, i), cfg,
                                       kind, n_groups)
            for i, kind in enumerate(pat)}
        p["rem"] = {kind + str(i): _init_block(
            jax.random.fold_in(k_stack, 100 + i), cfg, kind)
            for i, kind in enumerate(rem)}
    elif cfg.family == "encdec":
        p["enc"] = _stack_init(k_enc, cfg, "dense", cfg.n_enc_layers)
        p["ln_enc"] = norm_params(cfg, cfg.d_model)
        p["layers"] = _stack_init(k_stack, cfg, "cross", cfg.n_layers)
    else:  # dense / vlm
        p["layers"] = _stack_init(k_stack, cfg, "dense", cfg.n_layers)
    return p


# ---------------------------------------------------------------------------
# blocks (forward)
# ---------------------------------------------------------------------------

def _mlp(p, cfg, x):
    cd = cfg.cdtype()
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(cd))
    if is_gated(cfg.act):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cd))
        h = act_fn(cfg.act, h, g)
    else:
        h = act_fn(cfg.act, h)
    if cfg.mlp_weight_gathered:
        # keep everything sequence-sharded; the (gathered) weights are the
        # only model-axis traffic
        h = maybe_constrain(h, ("pod", "data"), "model", None)
    else:
        h = maybe_constrain(h, None, None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(cd))


def _self_attention(p, cfg, x, positions, causal, prefix_len, rope,
                    return_kv=False):
    out = attn.attention(p, cfg, x, positions, causal=causal, rope=rope,
                         prefix_len=prefix_len, return_kv=return_kv)
    return out if return_kv else (out, None)


def _block_fwd(p, cfg: ModelConfig, kind, x, positions, comm, mesh,
               causal=True, prefix_len=0, x_enc=None, rope=True,
               collect=False):
    """Returns (x, aux, cache_or_None)."""
    # sequence-parallel residual stream at the block boundary: saved (remat)
    # activations are sharded over the model axis too, not just data
    if cfg.seq_parallel and x.shape[1] % max(1, _mesh_axis(mesh, "model")) == 0:
        x = maybe_constrain(x, ("pod", "data"), "model", None)
    aux = jnp.float32(0.0)
    cache = None
    if kind == "ssm":
        h, state, conv_tail = ssm_mod.ssm_block(
            p["ssm"], cfg, norm(cfg, p["ln1"], x), return_tail=collect)
        if collect:
            cache = {"state": state, "conv": conv_tail}
        return x + h, aux, cache
    if kind == "rec":
        h, state, conv_tail = rglru_mod.rglru_block(
            p["rec"], cfg, norm(cfg, p["ln1"], x), return_tail=collect)
        if collect:
            cache = {"state": state, "conv": conv_tail}
        x = x + h
        return x + _mlp(p["mlp"], cfg, norm(cfg, p["ln2"], x)), aux, cache
    use_ring = (cfg.attn_ring and not collect and mesh is not None
                and "model" in getattr(mesh, "shape", {})
                and x.shape[1] % mesh.shape["model"] == 0)
    if use_ring:
        a, kv = attn.attention_ring(
            p["attn"], cfg, norm(cfg, p["ln1"], x), mesh, causal=causal,
            rope=rope, prefix_len=prefix_len), None
    else:
        a, kv = _self_attention(p["attn"], cfg, norm(cfg, p["ln1"], x),
                                positions, causal, prefix_len, rope,
                                return_kv=collect)
    if collect:
        cache = {"sa": {"k": kv[0], "v": kv[1]}}
    x = x + a
    if kind == "cross":
        kv_x = attn.encode_kv(p["xattn"], cfg, x_enc)
        x = x + attn.attention_cross(p["xattn"], cfg,
                                     norm(cfg, p["lnx"], x), kv_x)
        if collect:
            cache["xk"], cache["xv"] = kv_x
    if kind == "moe":
        x = maybe_constrain(x, ("pod", "data"), "model", None)
        h, aux = moe_mod.moe_block(p["moe"], cfg, norm(cfg, p["ln2"], x),
                                   comm, mesh)
        aux = jnp.float32(aux)
        x = x + h
        x = maybe_constrain(x, ("pod", "data"), None, None)
    else:
        x = x + _mlp(p["mlp"], cfg, norm(cfg, p["ln2"], x))
    return x, aux, cache


def _maybe_remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def _run_stack(params, cfg, kind, x, positions, comm, mesh, causal=True,
               prefix_len=0, x_enc=None, rope=True, collect=False):
    fwd = partial(_block_fwd, cfg=cfg, kind=kind, positions=positions,
                  comm=comm, mesh=mesh, causal=causal,
                  prefix_len=prefix_len, x_enc=x_enc, rope=rope,
                  collect=collect)
    inner = _maybe_remat(cfg, lambda lp, xx: fwd(lp, x=xx))

    def body(xx, lp):
        y, aux, cache = inner(lp, xx)
        return y, (aux, cache)

    if cfg.scan_layers:
        x, (auxs, caches) = jax.lax.scan(body, x, params)
        return x, jnp.mean(auxs), caches
    auxs, caches = [], []
    n = jax.tree.leaves(params)[0].shape[0]
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], params)
        x, (a, c) = body(x, lp)
        auxs.append(a)
        caches.append(c)
    caches = (jax.tree.map(lambda *cs: jnp.stack(cs), *caches)
              if collect else None)
    return x, jnp.mean(jnp.stack(auxs)), caches


# ---------------------------------------------------------------------------
# top level
# ---------------------------------------------------------------------------

def _embed_in(params, cfg, tokens, frontend):
    cd = cfg.cdtype()
    x = params["embed"][tokens].astype(cd)
    if cfg.scale_embed:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(cd)
    prefix_len = 0
    if frontend is not None and cfg.family != "encdec":
        x = jnp.concatenate([frontend.astype(cd), x], axis=1)
        prefix_len = frontend.shape[1]
    return x, prefix_len


def _logits(params, cfg, x):
    x = norm(cfg, params["ln_f"], x)
    if cfg.tie_embeddings:
        w = params["embed"].astype(cfg.cdtype())
        out = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        out = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(
            cfg.cdtype()))
    return out.astype(jnp.float32)


def _encode(params, cfg, frontend):
    """Whisper encoder over stubbed frame embeddings (non-causal)."""
    cd = cfg.cdtype()
    x = frontend.astype(cd)
    pos = sinusoidal_positions(x.shape[1], cfg.d_model).astype(cd)
    x = x + pos[None]
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x, _, _ = _run_stack(params["enc"], cfg, "dense", x, positions,
                         CommConfig(), None, causal=False, rope=False)
    return norm(cfg, params["ln_enc"], x)


def _forward_impl(params, cfg: ModelConfig, tokens, frontend, comm, mesh,
                  collect):
    x, prefix_len = _embed_in(params, cfg, tokens, frontend)
    x = maybe_constrain(x, ("pod", "data"), None, None)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    caches = None
    if cfg.family == "hybrid":
        n_groups, rem = _hybrid_layout(cfg)
        pat = cfg.hybrid.pattern
        gparams = tuple(params["groups"][k + str(i)]
                        for i, k in enumerate(pat))

        def gbody(xx, lps):
            a = jnp.float32(0.0)
            cs = []
            for kind, lp in zip(pat, lps):
                xx, ai, c = _block_fwd(lp, cfg, kind, xx, positions, comm,
                                       mesh, collect=collect)
                a, cs = a + ai, cs + [c]
            return xx, (a, tuple(cs))

        if cfg.scan_layers:
            x, (auxs, gcaches) = jax.lax.scan(gbody, x, gparams)
            aux = jnp.mean(auxs)
        else:
            n_g = jax.tree.leaves(gparams)[0].shape[0]
            auxs, gc = [], []
            for i in range(n_g):
                lp = jax.tree.map(lambda a: a[i], gparams)
                x, (a, c) = gbody(x, lp)
                auxs.append(a)
                gc.append(c)
            aux = jnp.mean(jnp.stack(auxs))
            gcaches = (jax.tree.map(lambda *cs: jnp.stack(cs), *gc)
                       if collect else None)
        rem_caches = {}
        for i, kind in enumerate(rem):
            x, _, c = _block_fwd(params["rem"][kind + str(i)], cfg, kind, x,
                                 positions, comm, mesh, collect=collect)
            rem_caches[kind + str(i)] = c
        if collect:
            caches = {"groups": {k + str(i): gcaches[i]
                                 for i, k in enumerate(pat)},
                      "rem": rem_caches}
    elif cfg.family == "encdec":
        x_enc = _encode(params, cfg, frontend)
        pos_dec = sinusoidal_positions(
            tokens.shape[1], cfg.d_model).astype(cfg.cdtype())
        x = x + pos_dec[None]
        x, aux, caches = _run_stack(params["layers"], cfg, "cross", x,
                                    positions, comm, mesh, x_enc=x_enc,
                                    rope=False, collect=collect)
    else:
        kind = {"ssm": "ssm", "moe": "moe"}.get(cfg.family, "dense")
        x, aux, caches = _run_stack(params["layers"], cfg, kind, x,
                                    positions, comm, mesh,
                                    prefix_len=prefix_len, collect=collect)
        if collect:
            caches = {"layers": caches}
    logits = _logits(params, cfg, x)
    return logits, {"moe_drop": aux}, caches


def forward(params, cfg: ModelConfig, tokens, frontend=None,
            comm: CommConfig = CommConfig(), mesh=None):
    """Training/scoring forward.  tokens: (B, S) int32.
    Returns (logits (B, S_total, V) f32, aux dict)."""
    logits, aux, _ = _forward_impl(params, cfg, tokens, frontend, comm,
                                   mesh, collect=False)
    return logits, aux


def prefill(params, cfg: ModelConfig, tokens, frontend=None,
            comm: CommConfig = CommConfig(), mesh=None, max_len=None):
    """Prompt pass: logits + decode caches sized ``max_len``."""
    logits, aux, caches = _forward_impl(params, cfg, tokens, frontend, comm,
                                        mesh, collect=True)
    s = logits.shape[1]
    max_len = max_len or s
    caches = _finalize_caches(cfg, caches, s, max_len)
    return logits, caches


def _finalize_caches(cfg, caches, s, max_len):
    """Pad / roll collected prefill caches into decode layout."""
    win = min(cfg.window, max_len) if cfg.window else max_len

    def fix(tree):
        def leaf(path, a):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            return a
        return tree

    def fix_kv(kv):
        # kv: (..., B, S, hkv, dh) (leading layer-stack dims possible)
        k = kv["k"]
        if cfg.window and s > win:
            idx = (jnp.arange(s - win, s) % win)
            buf_shape = k.shape[:-3] + (win,) + k.shape[-2:]
            out = {}
            for key in ("k", "v"):
                buf = jnp.zeros(buf_shape, kv[key].dtype)
                out[key] = buf.at[..., idx, :, :].set(
                    kv[key][..., s - win:, :, :])
            return out
        pad = [(0, 0)] * k.ndim
        pad[-3] = (0, max_len - s)
        return {key: jnp.pad(kv[key], pad) for key in ("k", "v")}

    def walk(t):
        if isinstance(t, dict) and set(t) == {"k", "v"}:
            return fix_kv(t)
        if isinstance(t, dict):
            return {kk: walk(vv) for kk, vv in t.items()}
        if isinstance(t, tuple):
            return tuple(walk(vv) for vv in t)
        return t

    return walk(caches)


# ---------------------------------------------------------------------------
# serving: decode
# ---------------------------------------------------------------------------

def _block_decode(p, cfg, kind, x, cache, pos, rope=True):
    if kind == "ssm":
        h, cache = ssm_mod.ssm_decode(p["ssm"], cfg,
                                      norm(cfg, p["ln1"], x), cache)
        return x + h, cache
    if kind == "rec":
        h, cache = rglru_mod.rglru_decode(p["rec"], cfg,
                                          norm(cfg, p["ln1"], x), cache)
        x = x + h
        return x + _mlp(p["mlp"], cfg, norm(cfg, p["ln2"], x)), cache
    a, cache_sa = attn.attention_decode(p["attn"], cfg,
                                        norm(cfg, p["ln1"], x),
                                        cache["sa"], pos)
    x = x + a
    new_cache = {"sa": cache_sa}
    if kind == "cross":
        x = x + attn.attention_cross(p["xattn"], cfg,
                                     norm(cfg, p["lnx"], x),
                                     (cache["xk"], cache["xv"]))
        new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
    if kind == "moe":
        h, _ = moe_mod._moe_local(p["moe"], cfg, norm(cfg, p["ln2"], x))
        x = x + h
    else:
        x = x + _mlp(p["mlp"], cfg, norm(cfg, p["ln2"], x))
    return x, new_cache


def init_caches(cfg: ModelConfig, batch, max_len):
    """Zero decode caches, stacked to match the scanned stacks."""
    cd = cfg.cdtype()
    win = min(cfg.window, max_len) if cfg.window else max_len

    def attn_cache():
        return {"sa": attn.init_cache(cfg, batch, win, cd)}

    def rep(tree, n):
        return jax.tree.map(lambda a: jnp.zeros((n,) + a.shape, a.dtype),
                            tree)

    if cfg.family == "ssm":
        return {"layers": rep(ssm_mod.init_ssm_cache(cfg, batch, cd),
                              cfg.n_layers)}
    if cfg.family == "hybrid":
        n_groups, rem = _hybrid_layout(cfg)
        pat = cfg.hybrid.pattern
        out = {"groups": {}, "rem": {}}
        for i, kind in enumerate(pat):
            base = (rglru_mod.init_rglru_cache(cfg, batch, cd)
                    if kind == "rec" else attn_cache())
            out["groups"][kind + str(i)] = rep(base, n_groups)
        for i, kind in enumerate(rem):
            out["rem"][kind + str(i)] = (
                rglru_mod.init_rglru_cache(cfg, batch, cd)
                if kind == "rec" else attn_cache())
        return out
    if cfg.family == "encdec":
        c = attn_cache()
        enc_len = cfg.n_frontend_tokens
        c["xk"] = jnp.zeros((batch, enc_len, cfg.n_kv, cfg.d_head), cd)
        c["xv"] = jnp.zeros((batch, enc_len, cfg.n_kv, cfg.d_head), cd)
        return {"layers": rep(c, cfg.n_layers)}
    return {"layers": rep(attn_cache(), cfg.n_layers)}


def decode_step(params, cfg: ModelConfig, token, caches, pos,
                comm: CommConfig = CommConfig(), mesh=None):
    """One serving step.  token: (B, 1) int32; pos: scalar int32 (0-based
    index of this token).  Returns (logits (B, 1, V), new caches)."""
    cd = cfg.cdtype()
    x = params["embed"][token].astype(cd)
    if cfg.scale_embed:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(cd)
    if cfg.family == "encdec":
        pe = sinusoidal_positions(2 ** 15, cfg.d_model).astype(cd)
        x = x + jax.lax.dynamic_slice_in_dim(pe, pos, 1, axis=0)[None]

    if cfg.family == "hybrid":
        n_groups, rem = _hybrid_layout(cfg)
        pat = cfg.hybrid.pattern

        def gbody(xx, lps_caches):
            lps, cs = lps_caches
            new_cs = []
            for kind, lp, c in zip(pat, lps, cs):
                xx, nc = _block_decode(lp, cfg, kind, xx, c, pos)
                new_cs.append(nc)
            return xx, tuple(new_cs)

        gparams = tuple(params["groups"][k + str(i)]
                        for i, k in enumerate(pat))
        gcaches = tuple(caches["groups"][k + str(i)]
                        for i, k in enumerate(pat))
        if cfg.scan_layers:
            x, ncs = jax.lax.scan(gbody, x, (gparams, gcaches))
        else:
            n_g = jax.tree.leaves(gparams)[0].shape[0]
            accs = []
            for i in range(n_g):
                lp = jax.tree.map(lambda a: a[i], gparams)
                cc = jax.tree.map(lambda a: a[i], gcaches)
                x, nc = gbody(x, (lp, cc))
                accs.append(nc)
            ncs = jax.tree.map(lambda *cs: jnp.stack(cs), *accs)
        new_caches = {"groups": {k + str(i): ncs[i]
                                 for i, k in enumerate(pat)}, "rem": {}}
        for i, k in enumerate(rem):
            x, nc = _block_decode(params["rem"][k + str(i)], cfg, k, x,
                                  caches["rem"][k + str(i)], pos)
            new_caches["rem"][k + str(i)] = nc
    else:
        kind = {"ssm": "ssm", "moe": "moe",
                "encdec": "cross"}.get(cfg.family, "dense")

        def body(xx, lp_c):
            lp, c = lp_c
            xx, nc = _block_decode(lp, cfg, kind, xx, c, pos)
            return xx, nc

        if cfg.scan_layers:
            x, ncaches = jax.lax.scan(body, x, (params["layers"],
                                                caches["layers"]))
        else:
            accs = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                cc = jax.tree.map(lambda a: a[i], caches["layers"])
                x, nc = body(x, (lp, cc))
                accs.append(nc)
            ncaches = jax.tree.map(lambda *cs: jnp.stack(cs), *accs)
        new_caches = {"layers": ncaches}
    logits = _logits(params, cfg, x)
    return logits, new_caches


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig, mesh_shape: dict):
    """PartitionSpec tree parallel to init_params' output.

    TP/EP over "model"; ZeRO/FSDP over "data": every weight's d_model axis
    is additionally sharded over the data axis (when divisible) so params +
    optimizer state scale down with the FULL mesh, not just the model axis.
    XLA gathers weights on use (per scanned layer) and reduce-scatters the
    gradients -- the standard FSDP schedule.
    """
    tp = mesh_shape.get("model", 1)
    fs = mesh_shape.get("data", 1)

    def heads_ok(n):
        return n % tp == 0

    def dd(dim):  # fsdp-shard a dim when divisible
        return "data" if dim % fs == 0 else None

    dm = dd(cfg.d_model)
    qspec = P(dm, "model", None) if heads_ok(cfg.n_heads) else \
        P(dm, None, None)
    kvspec = P(dm, "model", None) if heads_ok(cfg.n_kv) else \
        P(dm, None, None)
    ospec = P("model", None, dm) if heads_ok(cfg.n_heads) else \
        P(None, None, dm)
    a = {"wq": qspec, "wk": kvspec, "wv": kvspec, "wo": ospec}
    if cfg.qk_norm:
        a["q_norm"] = {"scale": P(None)}
        a["k_norm"] = {"scale": P(None)}
    nrm = ({"scale": P(None)} if cfg.norm == "rms"
           else {"scale": P(None), "bias": P(None)})
    if cfg.mlp_weight_gathered:
        # weight-gathered mode: MLP replicated over model (FSDP over data
        # only); activations stay sequence-sharded through the block
        fsh = None if dm else dd(cfg.d_ff)
        mlp = {"w_in": P(dm, fsh), "w_out": P(fsh, dm)}
        if is_gated(cfg.act):
            mlp["w_gate"] = P(dm, fsh)
    else:
        mlp = {"w_in": P(dm, "model"), "w_out": P("model", dm)}
        if is_gated(cfg.act):
            mlp["w_gate"] = P(dm, "model")

    def block_spec(kind):
        if kind == "ssm":
            return {"ln1": nrm, "ssm": {
                "w_in": P("model", dd(2 * 2 * cfg.d_model)), "conv_w":
                P(None, None),
                "conv_b": P(None), "a_log": P(None), "dt_bias": P(None),
                "d_skip": P(None), "out_norm": nrm,
                "w_out": P(None, "model")}}
        if kind == "rec":
            dr = cfg.hybrid.d_rnn or cfg.d_model
            return {"ln1": nrm, "rec": {
                "w_x": P(dm, "model"), "w_y": P(dm, "model"),
                "conv_w": P(None, "model"), "conv_b": P("model"),
                "w_r": P("model", dd(dr)), "w_i": P("model", dd(dr)),
                "lam": P(None), "w_out": P("model", dm)},
                "ln2": nrm, "mlp": mlp}
        if kind == "moe":
            mspec = {"router": P(None, None),
                     "w_in": P("model", dm, None),
                     "w_out": P("model", None, dm)}
            if is_gated(cfg.act):
                mspec["w_gate"] = P("model", dm, None)
            return {"ln1": nrm, "attn": a, "ln2": nrm, "moe": mspec}
        if kind == "cross":
            return {"ln1": nrm, "attn": a, "lnx": nrm, "xattn": a,
                    "ln2": nrm, "mlp": mlp}
        return {"ln1": nrm, "attn": a, "ln2": nrm, "mlp": mlp}

    def stacked(spec):
        return jax.tree.map(lambda s: P(None, *s), spec,
                            is_leaf=lambda s: isinstance(s, P))

    vshard = "model" if cfg.vocab % tp == 0 else None
    vdata = "data" if cfg.vocab % fs == 0 else None
    out = {"embed": P(vshard, dm if vshard else (dm or vdata)), "ln_f": nrm}
    if not cfg.tie_embeddings:
        out["lm_head"] = P(dm, vshard)
    if cfg.family == "ssm":
        out["layers"] = stacked(block_spec("ssm"))
    elif cfg.family == "moe":
        out["layers"] = stacked(block_spec("moe"))
    elif cfg.family == "hybrid":
        n_groups, rem = _hybrid_layout(cfg)
        pat = cfg.hybrid.pattern
        out["groups"] = {k + str(i): stacked(block_spec(k))
                         for i, k in enumerate(pat)}
        out["rem"] = {k + str(i): block_spec(k) for i, k in enumerate(rem)}
    elif cfg.family == "encdec":
        out["enc"] = stacked(block_spec("dense"))
        out["ln_enc"] = nrm
        out["layers"] = stacked(block_spec("cross"))
    else:
        out["layers"] = stacked(block_spec("dense"))
    return out


def cache_specs(cfg: ModelConfig, mesh_shape: dict, caches, dp=None):
    """PartitionSpec tree for decode caches: batch over data axes, kv heads
    over model when divisible."""
    tp = mesh_shape.get("model", 1)
    if dp is None:
        dp = tuple(a for a in ("pod", "data") if a in mesh_shape)
    kvm = "model" if cfg.n_kv % tp == 0 else None

    def leaf_spec(path, a):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        top = path[0].key if hasattr(path[0], "key") else str(path[0])
        lead = () if top == "rem" else (None,)       # layer-stacked?
        if name in ("k", "v", "xk", "xv"):
            return P(*lead, dp, None, kvm, None)
        if name == "state" and a.ndim - len(lead) == 4:   # ssm state
            return P(*lead, dp, kvm, None, None)
        if name == "state":                                # rglru state
            return P(*lead, dp, None)
        if name == "conv":
            return P(*lead, dp, None, None)
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, caches)
