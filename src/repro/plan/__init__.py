"""Declarative plan space + cost-model-guided search (DESIGN.md #12).

``space``     -- the enumerable cross-product of every plan-time knob
                 (comm strategy, chunking, relayout fold, chunk axis,
                 execution order policy, Hockney doubling mode, relayout
                 schedule, Pallas FFT radix, process-mesh shape).
``costmodel`` -- an analytic bytes/FLOPs/latency predictor for any point
                 of the space, evaluated WITHOUT lowering or compiling;
                 its byte counts are asserted bit-for-bit against
                 ``launch.hlo_stats.comm_bytes_stats`` on lowered HLO.
``search``    -- predictor-pruned frontier search: rank the space with the
                 cost model, wall-clock-time only a shortlist (reusing the
                 ``autotune_comm`` budget/census machinery), persist the
                 winners in the schema-versioned $REPRO_COMM_CACHE JSON.
"""
from repro.plan.space import (PlanPoint, PlanSpace, mesh_shapes_for)
from repro.plan.costmodel import (CostModel, predict_bytes, switch_traces)
from repro.plan.search import (SHORTLIST_DIVISOR, guided_comm_candidates,
                               search_plan)

__all__ = [
    "PlanPoint", "PlanSpace", "mesh_shapes_for",
    "CostModel", "predict_bytes", "switch_traces",
    "SHORTLIST_DIVISOR", "guided_comm_candidates", "search_plan",
]
