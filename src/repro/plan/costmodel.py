"""Analytic bytes/FLOPs/latency predictor for plan points (DESIGN.md #12).

``launch.hlo_stats.comm_bytes_stats`` MEASURES per-collective operand
bytes on lowered HLO; this module PREDICTS the same numbers from the plan
alone -- no lowering, no compile -- by replaying the distributed
pipeline's shape algebra (``distributed.pencil``):

Let ``order = (d0, d1, d2)`` be the plan's execution order, ``U[d] =
Plan1D.valid_in`` (live physical extent outside d's own transform),
``S[d] = Plan1D.n_out`` (spectral extent), and ``PU/PS`` those extents
padded up to the mesh-axis multiple XLA's all-to-all requires.  The four
topology switches then see, per rank, exactly:

  ========  ====  =========================================  =====  =====
  switch    axis  local dims {d0, d1, d2}                    split  chunk
  ========  ====  =========================================  =====  =====
  fwd a1    p1    PS0,      PU1/p1,   PU2/p2                 d0     d2
  fwd a2    p2    PS0/p1,   PS1,      PU2/p2                 d1     d0
  bwd a2    p2    PS0/p1,   PS1/p2,   PU2                    d2     d0
  bwd a1    p1    PS0/p1,   PU1,      PU2/p2                 d1     d2
  ========  ====  =========================================  =====  =====

(The ``chunk`` column is the uninvolved grid axis the chunked strategies
cut when no free batch axis applies.)  An operand is complex once the
first r2c/c2c transform in execution order has run forward and until it
runs backward; the dims are IDENTICAL across relayout baseline/scheduled
and fold pack/unpack -- a permutation reorders axes, never changes the
payload -- which is why only strategy/n_chunks/order/doubling/mesh move
bytes.  ``tests/test_plansearch.py`` asserts ``predict_bytes`` equals the
HLO measurement bit-for-bit across the sampled space.

On top of the exact byte counts, ``CostModel`` adds a latency/bandwidth/
FLOPs time estimate (alpha-beta model plus a 5 n log2 n transform term
and an overlap discount) -- heuristic, used ONLY to rank candidates; the
guided-search guarantees are enforced empirically by the oracle tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

__all__ = ["SwitchTrace", "switch_traces", "predict_bytes",
           "predict_collectives", "CostModel"]


def _ceil_to(n: int, p: int) -> int:
    return -(-n // p) * p


@dataclass(frozen=True)
class SwitchTrace:
    """Shape facts of ONE topology switch (per rank, pre-collective)."""

    index: int              # program order, 0..3
    axis_size: int          # ranks of the mesh axis the switch runs over
    dims: tuple             # ((logical_dim, local_extent), ...) sorted by dim
    split_dim: int          # logical dim the collective splits
    chunk_dim: int          # uninvolved grid dim (chunked-strategy fallback)
    is_complex: bool        # operand dtype is complex at this switch

    @property
    def elems(self) -> int:
        n = 1
        for _, e in self.dims:
            n *= e
        return n

    def extent(self, dim: int) -> int:
        return dict(self.dims)[dim]


def switch_traces(plan, p1: int, p2: int) -> tuple:
    """The four per-switch shape traces of ``plan`` on a (p1, p2) grid."""
    d0, d1, d2 = plan.order
    dirs = plan.dirs
    U = [p.valid_in for p in dirs]
    S = [p.n_out for p in dirs]
    PU1, PU2 = _ceil_to(U[d1], p1), _ceil_to(U[d2], p2)
    PS0, PS1 = _ceil_to(S[d0], p1), _ceil_to(S[d1], p2)
    # dft dims are a suffix of the execution order (r2r dims transform
    # first); the operand turns complex at the first dft dim's forward
    # transform and turns back real at its backward transform
    n_dft = sum(1 for d in plan.order if dirs[d].dft is not None)

    def mk(i, p, dims, split, chunk, cplx):
        return SwitchTrace(i, p, tuple(sorted(dims.items())), split, chunk,
                           bool(cplx))

    return (
        mk(0, p1, {d0: PS0, d1: PU1 // p1, d2: PU2 // p2}, d0, d2,
           dirs[d0].dft is not None),
        mk(1, p2, {d0: PS0 // p1, d1: PS1, d2: PU2 // p2}, d1, d0,
           n_dft >= 2),
        mk(2, p2, {d0: PS0 // p1, d1: PS1 // p2, d2: PU2}, d2, d0,
           n_dft >= 2),
        mk(3, p1, {d0: PS0 // p1, d1: PU1, d2: PU2 // p2}, d1, d2,
           n_dft >= 3),
    )


def _itemsize(dtype) -> int:
    return int(np.dtype(dtype).itemsize)


def predict_collectives(plan, p1: int, p2: int, dtype, cfg,
                        batch=None) -> list:
    """Per-collective prediction in program order: one dict per emitted
    all-to-all -- ``{"switch", "bytes", "chunked", "padded"}``.

    ``batch`` is the in-block multi-RHS extent riding every switch (the
    chunked strategies' preferred free chunk axis), ``None`` when absent.
    ``padded`` marks a chunk whose axis did not divide ``n_chunks`` (the
    solve-time zero-padding ``core.comm._split_chunks`` warns about).
    """
    item = _itemsize(dtype)
    chunked = cfg.strategy in ("pipelined", "overlap") and cfg.n_chunks > 1
    nc = cfg.n_chunks if chunked else 1
    out = []
    for sw in switch_traces(plan, p1, p2):
        if sw.axis_size == 1:
            continue        # 1-rank mesh axis: the switch lowers to a
            # local reshape, no collective is emitted
        eb = item * (2 if sw.is_complex else 1)
        core = sw.elems * (batch if batch is not None else 1)
        if nc == 1:
            out.append({"switch": sw.index, "bytes": core * eb,
                        "chunked": False, "padded": False})
            continue
        # chunk-axis resolution mirrors CommStrategy._chunk_axis: the
        # batch axis when present, preferred ("auto") and dividing;
        # otherwise the uninvolved grid dim, zero-padded if non-dividing
        if (batch is not None and cfg.chunk_axis == "auto"
                and batch % nc == 0):
            per, padded = core // nc * eb, False
        else:
            ln = sw.extent(sw.chunk_dim)
            cl = -(-ln // nc)
            per = core // ln * cl * eb
            padded = bool(ln % nc)
        out.extend({"switch": sw.index, "bytes": per,
                    "chunked": True, "padded": padded}
                   for _ in range(nc))
    return out


def predict_bytes(plan, p1: int, p2: int, dtype, cfg, batch=None) -> list:
    """Program-order per-collective operand bytes -- the exact counterpart
    of ``[p["bytes"] for p in comm_bytes_stats(hlo)["per_collective"]]``
    on the lowered solve (asserted bit-for-bit in test_plansearch.py)."""
    return [c["bytes"] for c in
            predict_collectives(plan, p1, p2, dtype, cfg, batch=batch)]


# -- time model --------------------------------------------------------------

def _stages(n: int, max_radix: int) -> int:
    """Stockham stage count of a length-n transform (radix-4 with one
    radix-2 absorbing an odd log2 factor; pure radix-2 under max_radix=2)
    -- mirrors ``kernels.fft_stockham.stage_count`` without importing the
    Pallas toolchain."""
    lg = max(int(math.log2(max(n, 2))), 1)
    return lg if max_radix < 4 else (lg + 1) // 2


@dataclass(frozen=True)
class CostModel:
    """alpha-beta-gamma time predictor over plan points.

    ``alpha_s``: per-collective dispatch/latency cost; ``bytes_per_s``:
    effective all-to-all wire bandwidth per rank; ``flops_per_s``:
    effective 1-D transform throughput; ``overlap_eff``: fraction of
    in-flight wire time the ``overlap`` strategy hides behind per-chunk
    transforms; ``pipeline_eff``: the (smaller) comm/comm overlap of
    ``pipelined``.  Absolute values are host-calibrated guesses -- only
    the RANKING matters, and the oracle tests hold that ranking to a 10%
    regret bound against brute force.
    """

    alpha_s: float = 40e-6
    bytes_per_s: float = 8e9
    flops_per_s: float = 5e9
    overlap_eff: float = 0.6
    pipeline_eff: float = 0.25

    def transform_seconds(self, plan, batch=None, max_radix: int = 4):
        """Per-direction 1-D transform time: 5 n log2(n) flops per row
        element (halved for real transforms), scaled by the Stockham
        stage-count ratio when a radix cap lengthens the kernel."""
        dirs = plan.dirs
        rows_all = (batch if batch is not None else 1)
        ext = [p.valid_in for p in dirs]
        out = {}
        for d, p in enumerate(dirs):
            rows = rows_all
            for o, e in enumerate(ext):
                if o != d:
                    rows *= e
            n = max(p.n_fft, 2)
            fl = 5.0 * rows * n * math.log2(n)
            if p.dft != "c2c":
                fl *= 0.5       # r2c / r2r: half-spectrum work
            fl *= _stages(n, max_radix) / max(_stages(n, 4), 1)
            out[d] = fl / self.flops_per_s
        return out

    def comm_cost(self, plan, p1: int, p2: int, dtype, cfg, batch=None,
                  max_radix: int = 4):
        """Predicted seconds of the four switch+transform stages under one
        comm config.  Returns ``(seconds, meta)`` where ``meta`` records
        ``bytes`` (total wire), ``collectives`` and ``padded`` (any chunk
        axis needed solve-time zero-padding)."""
        cols = predict_collectives(plan, p1, p2, dtype, cfg, batch=batch)
        tsec = self.transform_seconds(plan, batch=batch,
                                      max_radix=max_radix)
        d0, d1, d2 = plan.order
        # the post continuation each switch carries (fwd d1, fwd d2,
        # bwd d1, bwd d0) -- what the overlap strategy hides wire time with
        post = {0: tsec[d1], 1: tsec[d2], 2: tsec[d1], 3: tsec[d0]}
        total = tsec[d0] + tsec[d2]          # stages outside any switch
        padded = False
        for i in range(4):
            sw_cols = [c for c in cols if c["switch"] == i]
            nc = len(sw_cols)
            wire = sum(c["bytes"] for c in sw_cols) / self.bytes_per_s
            padded = padded or any(c["padded"] for c in sw_cols)
            stage = self.alpha_s * nc + wire + post[i]
            if nc > 1:
                frac = (nc - 1) / nc
                if cfg.strategy == "overlap":
                    # chunk k's transform runs while chunk k+1 is on the
                    # wire: hide the smaller of the two, derated
                    stage -= self.overlap_eff * min(post[i] * frac,
                                                    wire * frac)
                else:
                    stage -= self.pipeline_eff * wire * frac
            total += stage
        meta = {"bytes": sum(c["bytes"] for c in cols),
                "collectives": len(cols), "padded": padded}
        return total, meta

    def plan_cost(self, point, plan, dtype, batch=None):
        """Cost of a full ``PlanPoint`` (its own mesh shape, radix, comm)
        -- the plan-level search's ranking key.  ``point.mesh_shape`` must
        be set."""
        p1, p2 = point.mesh_shape
        return self.comm_cost(plan, p1, p2, dtype, point.comm(),
                              batch=batch, max_radix=point.radix)
