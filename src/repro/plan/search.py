"""Cost-model-guided frontier search over the plan space (DESIGN.md #12).

Two levels:

* ``guided_comm_candidates`` -- the in-solver path behind
  ``DistributedPoissonSolver(comm="auto", autotune_search="guided")``:
  rank the comm sub-space (strategy x n_chunks x fold x chunk_axis) with
  the analytic predictor, drop chunked candidates whose solve-time
  zero-padding already costs more than the best monolithic plan, and hand
  only the shortlisted frontier to ``core.comm.autotune_comm`` (which
  keeps its budget/census/cache machinery -- the shortlist labels are
  part of the cache identity, so a model change can never replay a stale
  winner).
* ``search_plan`` -- the plan-level search over order_policy x doubling x
  relayout x radix x mesh shape ON TOP of the comm sub-space: plans are
  built with ``make_plan`` (cheap numpy, no lowering) for prediction,
  only the top-k points are compiled and wall-clock timed, and the winner
  is persisted in the schema-versioned $REPRO_COMM_CACHE JSON keyed by
  (shape-family, devices, dtype, engine).

The frontier policy is ``SHORTLIST_DIVISOR``: time ceil(space/6) of the
live candidates (>= 1), which on the default 12-candidate comm grid times
2 -- a 6x reduction, gated as ">= 5x fewer timed" by the oracle tests and
``bench_comm.py --search --check``.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field

from repro.core.comm import (cache_load_entries, cache_store_entry,
                             cfg_label)
from repro.plan.costmodel import CostModel
from repro.plan.space import PlanPoint, PlanSpace, mesh_shapes_for

__all__ = ["SHORTLIST_DIVISOR", "guided_comm_candidates", "PlanDecision",
           "search_plan"]

# fraction of the (post-prune) candidate space that gets wall-clock timed
SHORTLIST_DIVISOR = 6


def _shortlist_size(n_live: int, k=None) -> int:
    if k is not None:
        return max(1, min(int(k), n_live))
    return max(1, math.ceil(n_live / SHORTLIST_DIVISOR))


def guided_comm_candidates(plan, p1: int, p2: int, dtype, *, batch=None,
                           folds=("pack",), max_chunks: int = 4,
                           relayout: str = "scheduled", max_radix: int = 4,
                           model: CostModel = None, k=None,
                           census=None) -> tuple:
    """Predictor-ranked shortlist of ``CommConfig`` candidates for one
    solver instance (its plan, mesh extents, dtype and in-block batch).

    ``census`` (when a dict) is extended with the search's account:
    ``space`` (candidate count), ``predicted`` (label -> predicted
    seconds), ``pruned_padding`` (chunked candidates dropped because
    their zero-padding overhead exceeds the predicted win over the best
    monolithic plan) and ``shortlist`` (the labels handed to the timer).
    """
    model = model or CostModel()
    space = PlanSpace.comm(max_chunks=max_chunks, folds=folds,
                           batched=batch is not None, relayout=relayout)
    cands = space.comm_configs()
    preds, metas = {}, {}
    for cfg in cands:
        c, meta = model.comm_cost(plan, p1, p2, dtype, cfg, batch=batch,
                                  max_radix=max_radix)
        preds[cfg_label(cfg)] = c
        metas[cfg_label(cfg)] = meta
    # padding prune: a chunked candidate that needs solve-time zero-padding
    # AND does not even beat the best monolithic plan under the model has
    # no path to winning -- timing it is pure sweep cost (the prime-extent
    # regression in test_plansearch.py)
    mono_floor = min((preds[cfg_label(c)] for c in cands
                      if c.n_chunks == 1), default=float("inf"))
    pruned = [cfg_label(c) for c in cands
              if metas[cfg_label(c)]["padded"]
              and preds[cfg_label(c)] >= mono_floor]
    live = [c for c in cands if cfg_label(c) not in pruned]
    live.sort(key=lambda c: preds[cfg_label(c)])
    short = tuple(live[:_shortlist_size(len(live), k)])
    if census is not None:
        census["space"] = len(cands)
        census["predicted"] = preds
        census["pruned_padding"] = pruned
        census["shortlist"] = [cfg_label(c) for c in short]
    return short


# ---------------------------------------------------------------------------
# plan-level search (mesh shape / order / doubling / relayout / radix)
# ---------------------------------------------------------------------------

@dataclass
class PlanDecision:
    """Outcome of one ``search_plan`` run."""

    point: PlanPoint
    seconds: float = float("nan")     # measured winner time (nan on cache)
    timings: dict = field(default_factory=dict)   # label -> seconds
    census: dict = field(default_factory=dict)
    cached: bool = False


def _family_key(plan, n_devices: int, axes, dtype, engine: str,
                batch) -> str:
    """Shape-family identity of a persisted plan decision: what must match
    for a cached winner to be replayed."""
    return repr(("plansearch", 1,
                 tuple(p.n for p in plan.dirs),
                 tuple((p.bc.left.name, p.bc.right.name) for p in plan.dirs),
                 plan.dirs[0].layout.name,
                 int(n_devices), tuple(axes), str(dtype), engine, batch))


def search_plan(shape, L, bcs, *, layout=None, green_kind=None,
                dtype=None, engine: str = "xla", devices=None,
                axes=("data", "model"), mesh_shapes=None,
                order_policies=("layout", "natural"),
                doublings=("deferred",), relayouts=("scheduled",),
                max_chunks: int = 4, batch=None, k=None, reps: int = 3,
                budget_s=None, cache_path=None, model: CostModel = None,
                census=None, solver_kw=None) -> PlanDecision:
    """Search the FULL plan space for one problem and return the winner.

    Every (mesh_shape x order_policy x doubling x relayout x radix) combo
    is planned with ``make_plan`` (cheap, no lowering) and its comm
    sub-space predicted; only the global top-k points (default
    ceil(space/SHORTLIST_DIVISOR)) are built through ``get_solver`` and
    wall-clock timed.  The winner is persisted under ``cache_path``
    (default $REPRO_COMM_CACHE) in the schema-versioned JSON, keyed by
    shape family + device count + dtype + engine.
    """
    import os

    import jax
    import jax.numpy as jnp

    from repro.core.bc import DataLayout
    from repro.core import green as gr
    from repro.core.comm import _timed_call
    from repro.core.engine import TransformEngine
    from repro.core.solver import get_solver, make_plan

    layout = layout if layout is not None else DataLayout.CELL
    green_kind = green_kind if green_kind is not None else gr.GreenKind.CHAT2
    dtype = dtype if dtype is not None else jnp.float32
    model = model or CostModel()
    census = census if census is not None else {}
    devices = devices if devices is not None else jax.devices()
    n_dev = len(devices)
    if mesh_shapes is None:
        mesh_shapes = mesh_shapes_for(n_dev)
    if cache_path is None:
        cache_path = os.environ.get("REPRO_COMM_CACHE") or None

    ref_plan = make_plan(shape, L, bcs, layout, green_kind)
    fam = _family_key(ref_plan, n_dev, axes, jnp.dtype(dtype).name, engine,
                      batch)
    if cache_path:
        entry = cache_load_entries(cache_path, census=census).get(fam)
        if entry is not None:
            try:
                pt = PlanPoint.fromdict(entry["point"])
            except (KeyError, TypeError, ValueError):
                pt = None       # malformed entry: fall through to a search
            if pt is not None:
                return PlanDecision(pt, census=dict(census, cached=True),
                                    cached=True)

    space = PlanSpace.full(max_chunks=max_chunks, engine=engine,
                           batched=batch is not None,
                           order_policies=order_policies,
                           doublings=doublings, relayouts=relayouts,
                           mesh_shapes=mesh_shapes)
    plans, preds, metas = {}, {}, {}
    for pt in space.points():
        pk = (pt.order_policy, pt.doubling)
        if pk not in plans:
            plans[pk] = make_plan(shape, L, bcs, layout, green_kind,
                                  doubling=pt.doubling,
                                  order_policy=pt.order_policy)
        c, meta = model.plan_cost(pt, plans[pk], dtype, batch=batch)
        preds[pt] = c
        metas[pt] = meta
    mono_floor = min((c for pt, c in preds.items() if pt.n_chunks == 1),
                     default=float("inf"))
    pruned = [pt for pt in preds
              if metas[pt]["padded"] and preds[pt] >= mono_floor]
    live = sorted((pt for pt in preds if pt not in pruned),
                  key=preds.get)
    short = live[:_shortlist_size(len(live), k)]
    census.update(space=len(preds),
                  predicted={pt.label(): preds[pt] for pt in live},
                  pruned_padding=[pt.label() for pt in pruned],
                  shortlist=[pt.label() for pt in short])

    timings, failed, skipped = {}, {}, []
    kw = dict(solver_kw or {})

    def time_point(pt):
        import numpy as np
        from jax.sharding import Mesh
        p1, p2 = pt.mesh_shape
        mesh = Mesh(np.array(devices[:p1 * p2]).reshape(p1, p2), axes)
        eng = (TransformEngine("pallas", max_radix=pt.radix)
               if engine == "pallas" else engine)
        s = get_solver(shape, L, bcs, layout=layout, green_kind=green_kind,
                       mesh=mesh, axes=axes, comm=pt.comm(), dtype=dtype,
                       engine=eng, doubling=pt.doubling,
                       relayout=pt.relayout, order_policy=pt.order_policy,
                       **kw)
        f = np.ones(((batch,) if batch else ()) + tuple(s.input_shape),
                    dtype=jnp.dtype(dtype).name)
        s.solve(f).block_until_ready()            # compile + warm
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            s.solve(f).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return best

    for pt in short:
        lbl = pt.label()
        try:
            t, why = _timed_call(time_point, pt, budget_s)
        except Exception as e:      # noqa: BLE001 -- candidate may not build
            failed[lbl] = f"{type(e).__name__}: {e}"[:200]
            continue
        if why == "timeout":
            skipped.append(lbl)
            continue
        timings[lbl] = float(t)
    census.update(timed=dict(timings), failed=failed,
                  skipped_budget=skipped)
    if not timings:
        # every shortlisted point failed: fall back to the predictor's
        # best point (it is at least a valid plan)
        win = short[0] if short else PlanPoint(mesh_shape=mesh_shapes[0])
        return PlanDecision(win, timings=timings, census=census)
    by_label = {pt.label(): pt for pt in short}
    best_label = min(timings, key=timings.get)
    win = by_label[best_label]
    if cache_path:
        cache_store_entry(cache_path, fam, {
            "point": win.asdict(),
            "timings_us": {l: round(t * 1e6, 1)
                           for l, t in timings.items()}})
    return PlanDecision(win, seconds=timings[best_label], timings=timings,
                        census=census)
