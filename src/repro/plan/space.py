"""The declarative plan space (DESIGN.md #12).

A ``PlanPoint`` is one fully-specified execution plan for the distributed
solve: the comm sub-space (strategy x n_chunks x relayout fold x chunk
axis -- what ``core.comm.autotune_comm`` historically swept by brute
force) extended with the plan-level knobs that used to be fixed by the
caller: execution ``order_policy``, Hockney ``doubling`` mode, layout
``relayout`` schedule, Pallas FFT ``radix`` and the process-mesh shape
(P3DFFT's slab-vs-pencil decomposition knob).  ``PlanSpace`` enumerates a
validity-constrained cross-product of those dimensions; the cost model
(``plan.costmodel``) ranks the enumeration and ``plan.search`` times only
the shortlisted frontier.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace

from repro.core.comm import CHUNK_AXES, CommConfig, FOLDS, cfg_label

__all__ = ["ORDER_POLICIES", "DOUBLINGS", "RELAYOUTS", "RADIXES",
           "PlanPoint", "PlanSpace", "mesh_shapes_for"]

ORDER_POLICIES = ("layout", "natural")
DOUBLINGS = ("deferred", "upfront")
RELAYOUTS = ("scheduled", "baseline")
# Stockham kernel radix cap (kernels.fft_stockham): 4 = mixed radix-4/2
# (default), 2 = pure radix-2.  Only the Pallas engine executes it; the
# XLA engine's space is constrained to the default.
RADIXES = (4, 2)


@dataclass(frozen=True)
class PlanPoint:
    """One candidate execution plan -- every searchable knob, pinned."""

    strategy: str = "a2a"
    n_chunks: int = 1
    fold: str = "pack"
    chunk_axis: str = "auto"
    order_policy: str = "layout"
    doubling: str = "deferred"
    relayout: str = "scheduled"
    radix: int = 4
    mesh_shape: tuple | None = None    # (p1, p2); None = caller's mesh

    def comm(self) -> CommConfig:
        return CommConfig(self.strategy, self.n_chunks, self.fold,
                          self.chunk_axis)

    def label(self) -> str:
        """Human/cache label.  The comm sub-label matches
        ``core.comm.cfg_label`` exactly so solver-level census and
        plan-level census rows line up."""
        lbl = cfg_label(self.comm())
        for tag, val, default in (("order", self.order_policy, "layout"),
                                  ("dbl", self.doubling, "deferred"),
                                  ("lay", self.relayout, "scheduled"),
                                  ("r", self.radix, 4)):
            if val != default:
                lbl += f"|{tag}={val}"
        if self.mesh_shape is not None:
            lbl += f"|mesh={self.mesh_shape[0]}x{self.mesh_shape[1]}"
        return lbl

    def asdict(self) -> dict:
        return {"strategy": self.strategy, "n_chunks": self.n_chunks,
                "fold": self.fold, "chunk_axis": self.chunk_axis,
                "order_policy": self.order_policy,
                "doubling": self.doubling, "relayout": self.relayout,
                "radix": self.radix,
                "mesh_shape": (list(self.mesh_shape)
                               if self.mesh_shape is not None else None)}

    @classmethod
    def fromdict(cls, d: dict) -> "PlanPoint":
        ms = d.get("mesh_shape")
        return cls(str(d["strategy"]), int(d["n_chunks"]),
                   str(d.get("fold", "pack")),
                   str(d.get("chunk_axis", "auto")),
                   str(d.get("order_policy", "layout")),
                   str(d.get("doubling", "deferred")),
                   str(d.get("relayout", "scheduled")),
                   int(d.get("radix", 4)),
                   None if ms is None else tuple(int(p) for p in ms))


def _chunk_counts(max_chunks: int) -> tuple:
    out, nc = [], 2
    while nc <= max_chunks:
        out.append(nc)
        nc *= 2
    return tuple(out)


@dataclass(frozen=True)
class PlanSpace:
    """Validity-constrained cross-product of plan dimensions.

    Constraints applied by ``points()`` (so ``len(space)`` counts only
    distinct EXECUTABLE plans):

    * monolithic strategies (``a2a``/``fused``) carry ``n_chunks=1`` and
      the default chunk axis -- chunk knobs are meaningless there;
    * ``fold="unpack"`` exists only under ``relayout="scheduled"`` (the
      baseline pipelines never fold a permute into the switch);
    * ``chunk_axis="grid"`` is enumerated only when the space was built
      ``batched`` (without a free batch axis "auto" and "grid" pick the
      same axis);
    * ``radix != 4`` is enumerated only for the Pallas engine.
    """

    strategies: tuple = ("a2a", "fused", "pipelined", "overlap")
    chunk_counts: tuple = (2, 4)
    folds: tuple = ("pack",)
    chunk_axes: tuple = ("auto",)
    order_policies: tuple = ("layout",)
    doublings: tuple = ("deferred",)
    relayouts: tuple = ("scheduled",)
    radixes: tuple = (4,)
    mesh_shapes: tuple = (None,)

    @classmethod
    def comm(cls, max_chunks: int = 4, folds=("pack",), batched=False,
             relayout: str = "scheduled") -> "PlanSpace":
        """The comm sub-space one solver instance tunes over -- mirrors
        ``core.comm.autotune_candidates(max_chunks, folds)`` plus the
        chunk-axis dimension when an in-block batch is present."""
        return cls(chunk_counts=_chunk_counts(max_chunks),
                   folds=tuple(folds),
                   chunk_axes=CHUNK_AXES if batched else ("auto",),
                   relayouts=(relayout,))

    @classmethod
    def full(cls, n_devices: int = None, max_chunks: int = 4,
             engine: str = "xla", batched=False,
             order_policies=ORDER_POLICIES, doublings=("deferred",),
             relayouts=RELAYOUTS, mesh_shapes=None) -> "PlanSpace":
        """The plan-level space ``plan.search.search_plan`` explores."""
        if mesh_shapes is None:
            mesh_shapes = (mesh_shapes_for(n_devices)
                           if n_devices else (None,))
        folds = ("pack", "unpack") if "scheduled" in relayouts else ("pack",)
        return cls(chunk_counts=_chunk_counts(max_chunks), folds=folds,
                   chunk_axes=CHUNK_AXES if batched else ("auto",),
                   order_policies=tuple(order_policies),
                   doublings=tuple(doublings), relayouts=tuple(relayouts),
                   radixes=RADIXES if engine == "pallas" else (4,),
                   mesh_shapes=tuple(mesh_shapes))

    def points(self):
        """Yield every valid ``PlanPoint`` (deduplicated)."""
        for (rel, order, dbl, radix, ms) in itertools.product(
                self.relayouts, self.order_policies, self.doublings,
                self.radixes, self.mesh_shapes):
            folds = self.folds if rel == "scheduled" else ("pack",)
            for fold in folds:
                for strat in self.strategies:
                    chunked = strat in ("pipelined", "overlap")
                    ncs = self.chunk_counts if chunked else (1,)
                    cas = self.chunk_axes if chunked else ("auto",)
                    for nc, ca in itertools.product(ncs, cas):
                        yield PlanPoint(strat, nc, fold, ca, order, dbl,
                                        rel, radix, ms)

    def __len__(self) -> int:
        return sum(1 for _ in self.points())

    def comm_configs(self) -> tuple:
        """The comm sub-space as ``CommConfig`` candidates, in enumeration
        order (what feeds ``autotune_comm``)."""
        seen, out = set(), []
        for pt in self.points():
            cfg = pt.comm()
            if cfg not in seen:
                seen.add(cfg)
                out.append(cfg)
        return tuple(out)


def mesh_shapes_for(n_devices: int, include_slabs: bool = True) -> tuple:
    """Candidate (p1, p2) process grids for ``n_devices`` ranks: every
    factor pair, slab decompositions (a 1-sized axis) included -- P3DFFT's
    observation that the mesh shape is itself a first-order tuning knob.
    Ordered squarest-first (the usual pencil prior)."""
    shapes = []
    for p1 in range(1, n_devices + 1):
        if n_devices % p1 == 0:
            p2 = n_devices // p1
            if include_slabs or (p1 > 1 and p2 > 1):
                shapes.append((p1, p2))
    return tuple(sorted(shapes, key=lambda s: (abs(s[0] - s[1]), s)))
