"""Resilient solve runtime (DESIGN.md #10).

``faults``      deterministic fault injection (the chaos-test substrate)
``resilience``  graceful-degradation ladder, retry policy, SolveError
``health``      numerical health guards (NaN/Inf, spectral/FD residual)
"""
from . import faults, health, resilience  # noqa: F401

from .resilience import SolveError  # noqa: F401
