"""Resilient solve runtime (DESIGN.md #10).

``faults``      deterministic fault injection (the chaos-test substrate)
``resilience``  graceful-degradation ladder, retry policy, SolveError
``health``      numerical health guards (NaN/Inf, spectral/FD residual)
``abft``        algorithm-based fault tolerance: per-stage checksum
                invariants, wire sidecars, localize-and-recompute
                (DESIGN.md #13)
"""
from . import abft, faults, health, resilience  # noqa: F401

from .abft import IntegrityError  # noqa: F401
from .resilience import SolveError  # noqa: F401
