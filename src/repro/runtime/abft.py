"""Algorithm-based fault tolerance for the FFT Poisson solve (DESIGN.md #13).

``verify="nan"`` catches non-finite values and ``verify="residual"`` is a
whole-solve check with full re-solve as the only remedy; neither sees the
dominant large-machine failure mode -- silent data corruption, a bit flip
landing a wrong-but-FINITE value in a transform stage, a packed collective
payload, or a checkpoint leaf.  This module exploits the solve's algebraic
structure to detect, LOCALIZE, and selectively repair such corruption:

Per-stage linearity checksum ("weighted row checksum")
    Every 1-D transform ``T`` is linear along its active axis, so it
    commutes with summing the block's rows::

        sum_rows T(x)  ==  T(sum_rows x)

    Each checked stage snapshots the row sum BEFORE the stage runs,
    re-applies the 1-D primitive to that single reference row (under
    ``faults.suppressed()``, so an armed fault spec cannot corrupt both
    sides identically), and compares.  A mismatch localizes corruption to
    exactly that stage of that (chunk of the) pipeline.

Parseval energy (forward stages)
    The unnormalized r2r kinds satisfy ``sum w_out y^2 = sum w_in x^2 /
    normfact`` with the per-kind endpoint weights of the PR-2 Parseval
    test net (``tests/test_transforms.py``), and the DFT directions the
    classical ``sum w |X_k|^2 = n_fft * sum |x|^2`` (half-spectrum
    interior bins weighted 2).  A quadratic invariant independent of the
    linear checksum: corruption crafted to cancel in a row sum still
    shifts the energy.

Green-multiply invariant
    The pointwise pass is itself linear in ``yhat``, so
    ``sum(green_multiply(yhat, green)) == sum(yhat * green)`` -- one extra
    fused multiply-reduce verifies the solve's only O(N^3) pointwise pass.

Checksum-carrying collectives
    ``CommStrategy`` computes one checksum per destination rank over the
    packed payload of every topology switch and ships the length-P
    checksum row through the same switch (a sidecar ``all_to_all`` of P
    scalars -- negligible wire cost next to the payload).  The receiver
    re-reduces each source rank's slab and compares: a mismatch there but
    NOT in the surrounding compute stages attributes the corruption to
    the wire.  Composes with valid-extent crops (checksums are computed on
    the prepared payload), chunked strategies (per-chunk sidecars) and
    scheduled relayouts (permutes happen before packing).

Localize -> recompute -> escalate
    A checked compute stage retries ITSELF inline (``lax.cond`` on the
    traced mismatch): the retry branch re-executes only the implicated
    stage from its still-live input, so a transient flip is repaired
    without re-running the solve -- and because fault-plan hits are
    consumed in trace order, a ``count``-limited (transient) spec does not
    re-fire on the retry while a ``count=-1`` (persistent) one does.  The
    host inspects the per-stage mismatch report after the solve:
    repaired stages become ``stats["integrity"]`` records (mirroring
    ``stats["degradations"]``); unrepaired compute corruption raises
    ``IntegrityError`` (non-transient -> the PR-6 ladder degrades config
    rungs and terminally raises ``SolveError``); wire corruption raises a
    TRANSIENT ``IntegrityError`` (the remedy for a flipped link payload is
    re-sending, i.e. the ladder's backoff-retry path re-dispatches).

Two-phase guard (``verify="abft"``)
    Full per-stage checking reads every stage's block at least twice; at
    validation sizes that is comparable to the FFT work itself.  The
    production mode therefore runs a CHEAP end-to-end detector on every
    solve -- the Freivalds-style linearity sandwich ``<r, S f> == <S^T r,
    f>`` with a fixed deterministic probe ``r`` and the plan-time weight
    ``w = S^T r`` (one vjp of the linear solve, cached per config): two
    fused multiply-reduces per solve, no extra collectives beyond the
    XLA-generated scalar reduction.  Only when the sandwich trips does the
    solve re-dispatch through the fully-checked pipeline above to
    localize the stage, selectively repair it, and attribute wire vs
    compute -- the detect-cheap / localize-precise ladder.
    ``verify="abft-stages"`` runs the checked pipeline unconditionally
    (the chaos net's mode, and the right one for non-reproducible
    transients that a re-dispatch would not re-observe).

Everything here is gated on a ``Collector`` being passed: with
``verify="abft"`` off the pipelines pass ``col=None`` and not a single
checksum op is traced -- the verify-off path stays bit-exact.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

from repro.runtime import faults as _faults

__all__ = ["IntegrityError", "Collector", "tol_for", "checked_fwd_chunk",
           "checked_bwd_chunk", "checked_fwd_last", "checked_bwd_last",
           "checked_green", "wire_checksums", "wire_verify",
           "verify_report", "DEFAULT_RETRIES", "lite_probe",
           "lite_probe_axes", "lite_mismatch", "lite_mismatch_ab",
           "LITE_HEADROOM"]

# inline recompute attempts per checked stage before the host escalates
DEFAULT_RETRIES = 1

# headroom multiplier on tol_for for the end-to-end linearity sandwich:
# the detector compares two O(N)-term reductions routed through the FULL
# pipeline (every stage's roundoff accumulates into both sides), so its
# noise floor sits well above a single stage's
LITE_HEADROOM = 50.0

_TINY = 1e-30


class IntegrityError(RuntimeError):
    """Corruption detected by an ABFT invariant.  ``stage`` carries the
    provenance (``verify.abft@<check>``); ``transient`` follows the wire
    vs compute attribution (wire -> retry-worthy, compute -> ladder)."""

    def __init__(self, msg: str, *, stage=None, mismatch=None,
                 transient: bool = False):
        super().__init__(msg)
        self.stage = stage
        self.mismatch = mismatch
        self.transient = transient


def tol_for(dtype) -> float:
    """Relative checksum tolerance for a data dtype: well above roundoff
    accumulation of the block-sized reductions, well below the relative
    signature of any meaningful corruption."""
    return 1e-8 if np.finfo(np.dtype(dtype)).eps < 1e-10 else 3e-4


class Collector:
    """Trace-time accumulator of named mismatch scalars.

    Built fresh inside each abft jit wrapper: stages append (name, traced
    scalar) pairs while tracing; ``stacked()`` is the report vector the
    jitted function returns, and ``names`` (captured via a closure holder
    at trace time) gives the host the stage provenance of each slot."""

    __slots__ = ("names", "vals", "_stages")

    def __init__(self):
        self.names: list[str] = []
        self.vals: list = []
        self._stages: dict[str, int] = {}

    def unique(self, name: str) -> str:
        """Reserve a unique stage name (chunked stages check the same
        logical stage several times: ``fwd.1``, ``fwd.1#1``, ...)."""
        k = self._stages.get(name, 0)
        self._stages[name] = k + 1
        return f"{name}#{k}" if k else name

    def add(self, name: str, val):
        self.names.append(name)
        self.vals.append(jnp.asarray(val).astype(jnp.float32))

    def stacked(self):
        if not self.vals:
            return jnp.zeros((1,), jnp.float32)
        return jnp.stack(self.vals)


# ---------------------------------------------------------------------------
# mismatch arithmetic
# ---------------------------------------------------------------------------

def _floor(x, rows: float):
    """Cancellation-proof checksum scale: the expected magnitude of a sum
    of ``rows`` entries drawn at the block's rms, so a row sum that
    happens to cancel to ~0 does not turn roundoff into a false alarm."""
    rms = jnp.sqrt(jnp.mean(jnp.abs(x) ** 2))
    return rms * jnp.sqrt(jnp.asarray(rows, rms.dtype))


def _mismatch(got, ref, floor):
    num = jnp.max(jnp.abs(got - ref))
    den = jnp.maximum(jnp.maximum(jnp.max(jnp.abs(ref)),
                                  jnp.max(jnp.abs(got))), floor)
    return (num / (den + _TINY)).astype(jnp.float32)


def _bad(m, tol: float):
    return jnp.logical_or(m > tol, ~jnp.isfinite(m))


def _rows_sum(x, axis: int):
    axes = tuple(a for a in range(x.ndim) if a != axis)
    return jnp.sum(x, axis=axes)


# ---------------------------------------------------------------------------
# Parseval energy weights (the PR-2 test-net table, productionized)
# ---------------------------------------------------------------------------

def _r2r_energy_weights(kind, m: int):
    """Endpoint weights + scale of ``sum w_out y^2 = scale * sum w_in x^2``
    for the unnormalized scipy r2r conventions (scale = 1/normfact)."""
    from repro.core import transforms as tr
    name, t = kind.name[:3].lower(), int(kind.name[3])
    win = np.ones(m)
    wout = np.ones(m)
    if t == 1 and name == "dct":
        win[0] = win[-1] = 0.5
        wout = win.copy()
    elif t == 2:
        wout[0 if name == "dct" else -1] = 0.5
    elif t == 3:
        win[0 if name == "dct" else -1] = 0.5
    return win, wout, 1.0 / tr.r2r_normfact(kind, m)


def _parseval_weights(p):
    """``(w_in_live, w_out, scale)`` for direction ``p``'s forward
    transform, or ``(None, None, None)`` when no exact energy identity
    covers its storage (cropped c2c spectra)."""
    if p.category in ("sym", "semi"):
        win, wout, scale = _r2r_energy_weights(p.kind, p.n_fft)
        return win[:p.n_in], wout[:p.n_out], scale
    n_live = p.n_fft if p.pre_padded else p.n_in
    if p.dft == "r2c":
        if p.n_out != p.n_fft // 2 + 1:
            return None, None, None
        wout = np.full(p.n_out, 2.0)
        wout[0] = 1.0
        if p.n_fft % 2 == 0:
            wout[-1] = 1.0
    else:
        if p.n_out != p.n_fft:
            return None, None, None
        wout = np.ones(p.n_out)
    return np.ones(n_live), wout, float(p.n_fft)


def _energy_mismatch(x, y, p, axis: int):
    """Forward-stage Parseval check on the (already repaired) output."""
    win, wout, scale = _parseval_weights(p)
    if win is None:
        return None
    xa = jnp.moveaxis(x, axis, -1)
    ya = jnp.moveaxis(y, axis, -1)
    if not p.pre_padded:
        if p.flip:
            xa = xa[..., ::-1]
        xa = xa[..., p.in_start:p.in_start + p.n_in]
    rdt = jnp.abs(xa).dtype
    e_in = jnp.sum(jnp.abs(xa) ** 2 * jnp.asarray(win, rdt))
    e_out = jnp.sum(jnp.abs(ya) ** 2 * jnp.asarray(wout, rdt))
    ref = scale * e_in
    den = jnp.maximum(jnp.maximum(ref, e_out), _TINY)
    return (jnp.abs(e_out - ref) / den).astype(jnp.float32)


# ---------------------------------------------------------------------------
# checked stages (the sandwich: snapshot -> stage -> verify -> cond-retry)
# ---------------------------------------------------------------------------

def _checked_1d(x, p, sched, axis: int, fwd: bool, name: str, col, tol,
                retries: int):
    from repro.core import engine as _eng
    prim = _eng._fwd_last if fwd else _eng._bwd_last
    axis = axis % x.ndim
    if axis == x.ndim - 1:
        def apply(v):
            return prim(v, p, sched)
    else:
        def apply(v):
            return _eng.on_last_axis(v, axis, lambda w: prim(w, p, sched))
    if col is None:
        return apply(x)
    name = col.unique(name)
    rows = float(x.size // x.shape[axis])
    s_in = _rows_sum(x, axis)          # BEFORE the stage (and its taints)
    y = apply(x)
    with _faults.suppressed():         # reference row: no fault can touch it
        ref = prim(s_in[None], p, sched)[0]
    floor = _floor(x, rows)
    m = _mismatch(_rows_sum(y, axis), ref, floor)
    col.add(name, m)
    for _ in range(max(int(retries), 0)):
        # inline selective recompute: ONLY this stage re-executes, from its
        # still-live input, and only when the checksum tripped (lax.cond)
        y = lax.cond(_bad(m, tol), apply, lambda v: y, x)
        m = _mismatch(_rows_sum(y, axis), ref, floor)
    col.add(name + ".post", m)
    if fwd:
        em = _energy_mismatch(x, y, p, axis)
        if em is not None:
            col.add(name + ".energy", em)
    return y


def checked_fwd_chunk(x, d: int, sched, col, tol, retries=DEFAULT_RETRIES):
    """Natural-layout forward stage (baseline pipelines) with the ABFT
    sandwich; chunk-safe like ``TransformSchedule.fwd_chunk``."""
    from repro.core.engine import _batch_ndim
    p = sched.dirs[d]
    return _checked_1d(x, p, sched, _batch_ndim(x, sched) + p.dim, True,
                       f"fwd.{p.dim}", col, tol, retries)


def checked_bwd_chunk(x, d: int, sched, col, tol, retries=DEFAULT_RETRIES):
    from repro.core.engine import _batch_ndim
    p = sched.dirs[d]
    return _checked_1d(x, p, sched, _batch_ndim(x, sched) + p.dim, False,
                       f"bwd.{p.dim}", col, tol, retries)


def checked_fwd_last(x, d: int, sched, col, tol, retries=DEFAULT_RETRIES):
    """Layout-scheduled forward stage (active axis minor-most)."""
    p = sched.dirs[d]
    return _checked_1d(x, p, sched, x.ndim - 1, True, f"fwd.{p.dim}", col,
                       tol, retries)


def checked_bwd_last(x, d: int, sched, col, tol, retries=DEFAULT_RETRIES):
    p = sched.dirs[d]
    return _checked_1d(x, p, sched, x.ndim - 1, False, f"bwd.{p.dim}", col,
                       tol, retries)


def checked_green(yhat, green, sched, col, tol, retries=DEFAULT_RETRIES):
    """Green multiply with its linearity invariant + inline recompute."""
    if col is None:
        return sched.green_multiply(yhat, green)
    name = col.unique("green")

    def apply(v):
        return sched.green_multiply(v, green)

    from repro.kernels.ops import green_checksum
    y = apply(yhat)
    with _faults.suppressed():
        ref = green_checksum(yhat, jnp.asarray(green))
    floor = _floor(y, float(y.size))
    m = _mismatch(jnp.sum(y), ref, floor)
    col.add(name, m)
    for _ in range(max(int(retries), 0)):
        y = lax.cond(_bad(m, tol), apply, lambda v: y, yhat)
        m = _mismatch(jnp.sum(y), ref, floor)
    col.add(name + ".post", m)
    return y


# ---------------------------------------------------------------------------
# checksum-carrying collectives (used by repro.core.comm)
# ---------------------------------------------------------------------------

def wire_checksums(x, split_axis: int, parts: int):
    """Length-``parts`` checksum row of a packed payload: entry ``r`` is
    the full reduction of the sub-slab destined to rank ``r``.  Computed
    on the PREPARED payload (post crop/pad/permute), so it certifies
    exactly the bytes the collective moves."""
    sa = split_axis % x.ndim
    m = x.shape[sa]
    assert m % parts == 0, (m, parts)
    xr = jnp.reshape(jnp.moveaxis(x, sa, 0), (parts, -1))
    return jnp.sum(xr, axis=1)


def wire_verify(y, cs_recv, concat_axis: int, parts: int, col, name: str,
                tol):
    """Receive-side verification: re-reduce each source rank's gathered
    slab and compare with its shipped checksum.  Detect-only (the remedy
    for wire corruption is re-sending, i.e. the host's transient-retry
    path); returns ``y`` unchanged."""
    ca = concat_axis % y.ndim
    n = y.shape[ca]
    assert n % parts == 0, (n, parts)
    yr = jnp.reshape(jnp.moveaxis(y, ca, 0), (parts, -1))
    got = jnp.sum(yr, axis=1)
    floor = _floor(y, float(y.size // parts))
    col.add(col.unique(name), _mismatch(got, cs_recv, floor))
    return y


# ---------------------------------------------------------------------------
# end-to-end linearity sandwich (the cheap always-on tier)
# ---------------------------------------------------------------------------

def lite_probe(shape, dtype):
    """Deterministic unit-variance probe field ``r`` for the Freivalds
    sandwich.  Seeded from the shape (stable across processes), so the
    plan-time weight ``w = S^T r`` and every solve's probe agree."""
    import zlib
    seed = zlib.crc32(repr(tuple(shape)).encode())
    rng = np.random.default_rng(seed)
    return rng.standard_normal(tuple(shape)).astype(np.dtype(dtype))


def lite_probe_axes(grid_shape, dtype):
    """Separable (rank-1) probe ``r = q0 (x) q1 (x) q2`` for the
    distributed sandwich: per-axis factors with ``|q| in [0.5, 1.5]`` --
    bounded away from zero, so every entry of the outer product has
    magnitude >= 0.125 and no single-site corruption can hide in a small
    probe weight (a Gaussian probe has near-zero entries).  Rank-1
    structure lets the in-graph side contract ``<r, u>`` as three chained
    axis reductions reading ``u`` exactly once, instead of materializing
    (and streaming) a full probe field.  Deterministic per grid shape."""
    import zlib
    seed = zlib.crc32(repr(("r1",) + tuple(grid_shape)).encode())
    rng = np.random.default_rng(seed)
    dt = np.dtype(dtype)
    return [np.asarray(rng.uniform(0.5, 1.5, m) * rng.choice([-1.0, 1.0], m),
                       dtype=dt) for m in grid_shape]


def lite_mismatch_ab(a, b, floor) -> float:
    """Relative mismatch of the split sandwich: the in-graph side ``a =
    <r, u>`` (per-shard partials, host-folded) against the host side
    ``b = <w, f>`` computed while the device solve runs.  ``floor`` is
    ``||w||*||f||/sqrt(N)`` -- the natural scale of both dots -- so a
    near-orthogonal pair cannot turn roundoff into a false alarm.  The
    probe weights every entry (|r_i| >= 0.125), so NaN/Inf anywhere in
    ``u`` surfaces as a non-finite ``a`` -> inf mismatch."""
    a = np.atleast_1d(np.asarray(a, np.float64)).ravel()
    b = np.atleast_1d(np.asarray(b, np.float64)).ravel()
    fl = np.broadcast_to(np.atleast_1d(np.asarray(floor, np.float64)).ravel(),
                         a.shape)
    worst = 0.0
    for av, bv, fv in zip(a, b, fl):        # pod-batched: every report row
        if not (np.isfinite(av) and np.isfinite(bv) and np.isfinite(fv)):
            return float("inf")
        den = max(abs(av), abs(bv), fv, _TINY)
        worst = max(worst, abs(av - bv) / den)
    return worst


def lite_mismatch(triple) -> float:
    """Relative mismatch of the sandwich: ``triple = (<r,u>, <w,f>,
    ||u||^2)``.  The norm term floors the denominator so a pair of dots
    that happen to cancel cannot turn roundoff into a false alarm; any
    non-finite value reads as corruption (NaN/Inf taints trip it too)."""
    t = np.asarray(triple, dtype=np.float64).reshape(-1, 3)
    worst = 0.0
    for a, b, uu in t:                       # pod-batched: every report row
        if not (np.isfinite(a) and np.isfinite(b) and np.isfinite(uu)):
            return float("inf")
        den = max(abs(a), abs(b), float(np.sqrt(max(uu, 0.0))), _TINY)
        worst = max(worst, abs(a - b) / den)
    return worst


# ---------------------------------------------------------------------------
# host-side report verification
# ---------------------------------------------------------------------------

def _is_bad(v: float, tol: float) -> bool:
    return (not np.isfinite(v)) or v > tol


def verify_report(names, report, *, tol: float, stats=None,
                  describe: str = "solve"):
    """Inspect one solve's stacked mismatch report.

    Appends structured records to ``stats["integrity"]`` (mirroring
    ``stats["degradations"]``): ``action="recompute"`` for stages whose
    inline retry repaired the corruption, ``action="escalate"`` for
    surviving mismatches.  Raises ``IntegrityError`` when any check is
    still tripped after repair -- transient iff every surviving mismatch
    is wire-attributed.  Returns the repair records."""
    rep = np.asarray(report, dtype=np.float64)
    if rep.ndim > 1:                       # pod-batched solves: worst slot
        rep = rep.reshape(-1, rep.shape[-1]).max(axis=0)
    vals = dict(zip(names, rep))
    records, failures = [], []
    for nm in names:
        v = float(vals[nm])
        if nm.endswith(".post"):
            continue
        if nm.endswith(".energy"):
            # quadratic invariant: double roundoff sensitivity vs the
            # linear checksum -> 10x headroom on the same tolerance
            if _is_bad(v, 10.0 * tol):
                failures.append((nm, v, "energy"))
            continue
        if nm.startswith("wire."):
            if _is_bad(v, tol):
                failures.append((nm, v, "wire"))
            continue
        post = vals.get(nm + ".post")
        if post is None:
            if _is_bad(v, tol):
                failures.append((nm, v, "compute"))
        elif _is_bad(v, tol) and not _is_bad(float(post), tol):
            records.append({"stage": nm, "kind": "compute",
                            "mismatch": v, "post": float(post),
                            "action": "recompute", "attempts": 1})
        elif _is_bad(v, tol):
            failures.append((nm, v, "compute"))
    if stats is not None and (records or failures):
        ledger = stats.setdefault("integrity", [])
        ledger.extend(records)
        ledger.extend({"stage": nm, "kind": kind, "mismatch": v,
                       "action": "escalate"} for nm, v, kind in failures)
    if failures:
        if stats is not None:
            stats["verify_failures"] = stats.get("verify_failures", 0) + 1
        nm, v, kind = max(
            failures,
            key=lambda t: t[1] if np.isfinite(t[1]) else np.inf)
        raise IntegrityError(
            f"{describe}: ABFT {kind} checksum mismatch at {nm} "
            f"(mismatch {v:.3e}, tol {tol:.1e})",
            stage=f"verify.abft@{nm}", mismatch=v,
            transient=all(k == "wire" for _, _, k in failures))
    return records
