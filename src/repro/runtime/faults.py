"""Deterministic fault injection: the substrate of the chaos test suite.

A ``FaultPlan`` is a list of ``FaultSpec`` entries armed either by entering
the plan as a context manager or via the ``$REPRO_FAULTS`` environment
variable (a JSON list of spec dicts, or a path to a file holding one).
Production code is instrumented with three kinds of cheap hooks -- all of
them no-ops (one ``None`` check) when no plan is active:

``fail_point(stage)``
    Raises ``InjectedFault`` when a raising spec (kind ``error``,
    ``pallas_lowering``, ``device_loss`` or ``torn_write``) matches the
    hook's stage name.  Hooks sit at trace/dispatch boundaries
    (``solve.dispatch``, ``dist.dispatch``, ``pallas.fwd.<d>``,
    ``comm.<strategy>``, ``ckpt.leaf.<i>``), so an armed spec simulates a
    kernel failing at lowering, a collective dying, or a checkpoint write
    torn mid-leaf -- deterministically, at the same point every run.

``taint(stage, x)``
    Returns ``x`` with one entry overwritten by NaN/Inf (kinds ``nan`` /
    ``inf``) or perturbed by a finite delta (kind ``flip`` -- the silent-
    data-corruption model the ABFT layer must catch: a bit flip lands a
    wrong-but-finite value that ``verify="nan"`` is blind to).  The write
    is emitted at trace time, so the corruption rides inside the jitted
    pipeline exactly like a real numerical fault in that stage.

``should_fire(kind, step=k)``
    Driver-level poll (no raise): the ``launch.solve --steps`` loop asks it
    whether a ``device_loss`` spec fires at step ``k`` and then simulates
    the loss by shrinking the mesh and rebuilding the solver.

Spec matching is by ``fnmatch`` pattern over stage names, with ``after`` /
``count`` controlling which matching hits actually fire -- a ``count``-
limited spec models a transient fault (fires N times, then the retry
succeeds); ``count=-1`` models a hard fault that only a config downgrade
can route around (e.g. ``stage="pallas.*"`` disappears once the ladder
steps the engine down to xla).

Every firing is appended to ``FaultPlan.log`` so tests (and the CI chaos
job's artifact) can assert exactly which faults fired where.
"""
from __future__ import annotations

import fnmatch
import json
import os
import threading
import time
from dataclasses import dataclass, field

__all__ = ["InjectedFault", "FaultSpec", "FaultPlan", "active", "fail_point",
           "taint", "taint_host", "should_fire", "mangle_cache_entry",
           "plan_token", "plan_from_env", "suppressed"]

# raising kinds (fail_point); "stall" wedges the hook (sleeps ``seconds``)
# instead of raising -- the model of a hung collective / stuck worker the
# server drain deadline must survive; value kinds (taint) are "nan" /
# "inf" / "flip"; "corrupt_cache" is consumed by the autotune-cache loader
RAISING_KINDS = ("error", "pallas_lowering", "device_loss", "torn_write",
                 "stall")
VALUE_KINDS = ("nan", "inf", "flip")
KINDS = RAISING_KINDS + VALUE_KINDS + ("corrupt_cache",)


class InjectedFault(RuntimeError):
    """A fault raised by ``fail_point`` -- carries stage provenance and the
    transient flag the retry policy consults."""

    def __init__(self, stage: str, kind: str, transient: bool = False):
        super().__init__(f"injected {kind} fault at stage {stage!r}")
        self.stage = stage
        self.kind = kind
        self.transient = transient


@dataclass
class FaultSpec:
    """One armed fault.

    ``stage``: fnmatch pattern over hook stage names ("*" = everywhere).
    ``after``: skip this many matching hits before the first firing.
    ``count``: fire at most this many times (-1 = every matching hit).
    ``step``:  driver-step faults (``should_fire``) only fire when the
               polled step equals this (None = any step).
    ``transient``: mark raised faults retryable (the backoff path) instead
               of degradation-worthy.
    ``seconds``: ``stall`` kinds only -- how long the hook wedges.
    """

    kind: str
    stage: str = "*"
    after: int = 0
    count: int = 1
    step: int | None = None
    transient: bool = False
    seconds: float = 30.0
    hits: int = field(default=0, compare=False)
    fired: int = field(default=0, compare=False)

    def __post_init__(self):
        assert self.kind in KINDS, self.kind

    def _matches(self, stage: str) -> bool:
        return fnmatch.fnmatchcase(stage, self.stage)

    def _fire(self) -> bool:
        """Advance the hit counter; True when this hit fires."""
        self.hits += 1
        if self.hits <= self.after:
            return False
        if self.count >= 0 and self.fired >= self.count:
            return False
        self.fired += 1
        return True


class FaultPlan:
    """A deterministic set of armed faults; also a context manager."""

    def __init__(self, specs=()):
        self.specs = [s if isinstance(s, FaultSpec) else FaultSpec(**s)
                      for s in specs]
        self.log: list[dict] = []
        self._lock = threading.Lock()
        self._token = next(_TOKENS)

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self):
        _push(self)
        return self

    def __exit__(self, *exc):
        _pop(self)
        return False

    # -- matching ----------------------------------------------------------
    def _poll(self, stage: str, kinds, step=None):
        """First matching spec that fires at this hit, or None."""
        with self._lock:
            for s in self.specs:
                if s.kind not in kinds or not s._matches(stage):
                    continue
                if s.step is not None and s.step != step:
                    continue
                if s._fire():
                    self.log.append({"stage": stage, "kind": s.kind,
                                     "step": step, "hit": s.hits})
                    return s
        return None


_TOKENS = iter(range(1, 1 << 62))
_ACTIVE: list[FaultPlan] = []
_STACK_LOCK = threading.Lock()


def _push(plan: FaultPlan):
    with _STACK_LOCK:
        _ACTIVE.append(plan)


def _pop(plan: FaultPlan):
    with _STACK_LOCK:
        if plan in _ACTIVE:
            _ACTIVE.remove(plan)


def plan_from_env(env: str = "REPRO_FAULTS") -> FaultPlan | None:
    """Build (and activate) a plan from ``$REPRO_FAULTS``: a JSON list of
    FaultSpec dicts, or a path to a JSON file holding one.  Returns None
    when the variable is unset/empty.  The caller owns deactivation (use
    the returned plan as a context manager)."""
    raw = os.environ.get(env, "").strip()
    if not raw:
        return None
    if not raw.startswith("["):
        with open(raw) as fh:
            raw = fh.read()
    return FaultPlan(json.loads(raw))


_SUPPRESS = threading.local()


class suppressed:
    """Context manager making ``fail_point``/``taint`` no-ops on this
    thread.  The ABFT layer re-applies a transform to its checksum row to
    build the reference side of an invariant; without suppression an armed
    spec would fire a second time on that reference row and corrupt both
    sides of the comparison identically, hiding the fault."""

    def __enter__(self):
        self._prev = getattr(_SUPPRESS, "on", False)
        _SUPPRESS.on = True
        return self

    def __exit__(self, *exc):
        _SUPPRESS.on = self._prev
        return False


def _suppressed() -> bool:
    return getattr(_SUPPRESS, "on", False)


def active() -> FaultPlan | None:
    if _suppressed():
        return None
    return _ACTIVE[-1] if _ACTIVE else None


def plan_token():
    """Identity of the active plan (None when inactive) -- mixed into the
    ``get_solver`` cache key so solvers traced under an armed plan are
    never served to fault-free callers."""
    p = _ACTIVE[-1] if _ACTIVE else None
    return None if p is None else p._token


def fail_point(stage: str):
    """Raise ``InjectedFault`` when a raising spec matches this stage; a
    ``stall`` spec wedges the hook for ``spec.seconds`` instead (modelling
    a hung collective or stuck worker thread)."""
    p = active()
    if p is None:
        return
    s = p._poll(stage, RAISING_KINDS)
    if s is None:
        return
    if s.kind == "stall":
        time.sleep(s.seconds)
        return
    raise InjectedFault(stage, s.kind, transient=s.transient)


def _flip_delta(mod, flat):
    # finite SDC model: a high-bit flip perturbs one scalar by well above
    # the block's dynamic range (8*max + 1 keeps it finite yet decisive)
    return 8.0 * mod.max(mod.abs(flat)) + 1.0


def taint(stage: str, x):
    """Corrupt one entry of ``x`` when a value spec matches (trace-time:
    the corruption is part of the emitted computation).  ``nan``/``inf``
    overwrite; ``flip`` adds a finite out-of-range delta."""
    p = active()
    if p is None:
        return x
    s = p._poll(stage, VALUE_KINDS)
    if s is None:
        return x
    import jax.numpy as jnp
    flat = jnp.ravel(x)
    if s.kind == "flip":
        flat = flat.at[0].add(_flip_delta(jnp, flat).astype(flat.dtype))
    else:
        bad = jnp.inf if s.kind == "inf" else jnp.nan
        flat = flat.at[0].set(bad)
    return flat.reshape(x.shape)


def taint_host(stage: str, arr):
    """Host-side (numpy) variant of ``taint`` for data that never enters a
    trace -- checkpoint leaves read back from disk.  Models storage rot
    between save and restore; the manifest content digests must catch it."""
    p = active()
    if p is None:
        return arr
    s = p._poll(stage, VALUE_KINDS)
    if s is None:
        return arr
    import numpy as np
    out = np.array(arr)  # private copy; never rot the caller's buffer
    flat = out.reshape(-1)
    if s.kind == "flip":
        flat[0] += np.asarray(_flip_delta(np, flat), dtype=out.dtype)
    else:
        flat[0] = np.inf if s.kind == "inf" else np.nan
    return out


def should_fire(kind: str, step=None, stage: str = "driver") -> bool:
    """Driver-level poll (device loss at step k); never raises."""
    p = active()
    if p is None:
        return False
    return p._poll(stage, (kind,), step=step) is not None


def mangle_cache_entry(data: dict, stage: str = "autotune.cache"):
    """Corrupt a loaded autotune-cache dict in place when a
    ``corrupt_cache`` spec matches -- models on-disk cache rot; the loader
    must survive it (fall through to a live sweep)."""
    p = active()
    if p is None:
        return data
    s = p._poll(stage, ("corrupt_cache",))
    if s is not None and data:
        for k in data:
            data[k] = {"strategy": "bogus-strategy", "n_chunks": "NaN"}
    return data
