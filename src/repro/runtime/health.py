"""Numerical health guards: NaN/Inf detection and a cheap residual check.

``verify="nan"`` guards the solve OUTPUT (plus the input) for finiteness;
``verify="residual"`` additionally checks the relative 7-point
finite-difference Laplacian residual ``||lap_h(u) - f|| / ||f||`` on the
INTERIOR of the valid extents.  The residual is a consistency gate, not an
accuracy gate: the solver is spectral, the FD stencil is 2nd order, so a
healthy solve sits at discretization level (percent-ish on coarse grids)
while a corrupted one (NaN anywhere, a stage fed garbage, a wrong-layout
Green multiply) lands at NaN or O(1) -- the default ``rtol=0.5`` separates
the two decisively without false-failing coarse healthy solves.

When the output is non-finite, ``locate_nonfinite_stage`` re-runs the
reference (natural-layout) pipeline EAGERLY with a finiteness check after
every stage -- the per-stage NaN/Inf guard -- and the resulting stage name
becomes the ``HealthError`` provenance the ladder and ``SolveError``
report.
"""
from __future__ import annotations

import numpy as np

__all__ = ["HealthError", "check_finite", "fd_residual", "check_solution",
           "locate_nonfinite_stage"]


class HealthError(RuntimeError):
    """A numerical health guard tripped; carries stage provenance."""

    def __init__(self, msg: str, *, stage: str = "verify", detail=None):
        super().__init__(msg)
        self.stage = stage
        self.detail = detail
        self.transient = False


def _finite(x) -> bool:
    import jax.numpy as jnp
    return bool(jnp.isfinite(x).all())


def check_finite(name: str, x):
    if not _finite(x):
        raise HealthError(f"non-finite values at {name}", stage=name)


def fd_residual(u, f, plan) -> float:
    """Relative interior FD-Laplacian residual of ``u`` against ``f``.

    Works on user-shaped arrays (leading batch axes allowed); only the
    interior of each grid axis enters, so boundary conventions (overwritten
    Dirichlet zeros, node-periodic duplicated points) never pollute it.
    """
    import jax.numpy as jnp
    u = jnp.asarray(u)
    f = jnp.asarray(f)
    ndim = len(plan.dirs)
    off = u.ndim - ndim

    def shifted(x, d, s):
        sl = [slice(None)] * x.ndim
        for dd in range(ndim):
            lo, hi = 1, x.shape[off + dd] - 1
            if dd == d:
                lo, hi = lo + s, hi + s
            sl[off + dd] = slice(lo, hi)
        return x[tuple(sl)]

    lap = None
    for d, p in enumerate(plan.dirs):
        h2 = p.h * p.h
        term = (shifted(u, d, 1) - 2.0 * shifted(u, d, 0)
                + shifted(u, d, -1)) / h2
        lap = term if lap is None else lap + term
    f_int = shifted(f, -1, 0)
    num = jnp.linalg.norm(jnp.ravel(lap - f_int))
    den = jnp.linalg.norm(jnp.ravel(f_int))
    return float(num / jnp.maximum(den, np.finfo(np.float32).tiny))


def check_solution(u, f, plan, mode: str = "nan", rtol: float = 0.5,
                   stats: dict = None, locate=None):
    """The opt-in solve verifier.  ``mode``: "nan" (finiteness only) or
    "residual" (finiteness + FD residual below ``rtol``).  ``locate``, when
    given, maps a non-finite output to its first-bad-stage provenance."""
    assert mode in ("nan", "residual"), mode
    if not _finite(u):
        if stats is not None:
            stats["verify_failures"] = stats.get("verify_failures", 0) + 1
        stage = "verify.nan"
        if locate is not None:
            try:
                stage = "verify.nan@" + locate()
            except Exception:  # diagnosis is best-effort
                pass
        raise HealthError("solve output contains NaN/Inf", stage=stage)
    if mode == "residual":
        r = fd_residual(u, f, plan)
        if not np.isfinite(r) or r > rtol:
            if stats is not None:
                stats["verify_failures"] = \
                    stats.get("verify_failures", 0) + 1
            raise HealthError(
                f"FD residual {r:.3g} exceeds rtol={rtol} "
                f"(corrupted solve)", stage="verify.residual", detail=r)
        if stats is not None:
            stats["last_residual"] = r


def locate_nonfinite_stage(plan, sched, f, green) -> str:
    """Per-stage NaN/Inf guard: walk the reference (natural-layout, eager)
    pipeline and return the first stage whose output is non-finite.
    ``green`` is the NATURAL-layout transformed Green's function.  Used for
    provenance only -- numerically it is the baseline pipeline, which all
    scheduled variants are equivalent to."""
    import jax.numpy as jnp
    from repro.core.engine import (bwd_1d, fwd_1d, materialize_doubling)

    if not _finite(f):
        return "input"
    y = materialize_doubling(jnp.asarray(f), plan.dirs)
    for d in plan.order:
        y = fwd_1d(y, plan.dirs[d], sched)
        if not _finite(y):
            return f"fwd.{d}"
    y = sched.green_multiply(y, jnp.asarray(green).astype(y.dtype))
    if not _finite(y):
        return "green"
    for d in reversed(plan.order):
        y = bwd_1d(y, plan.dirs[d], sched)
        if not _finite(y):
            return f"bwd.{d}"
    return "output"
