"""Graceful-degradation ladder + retry policy (DESIGN.md #10).

Every configurable fast path of the solve has a documented slower-but-safer
fallback; on failure the runtime walks them one knob at a time:

    retry (bounded exponential backoff, transient errors only)
      -> engine    pallas    -> xla        (kernel lowering / exec faults)
      -> comm      overlap   -> pipelined -> a2a   (collective faults)
                   fused     -> pipelined
      -> relayout  scheduled -> baseline   (fused-transpose faults)
      -> doubling  deferred  -> upfront    (pruned-extent faults)

Each downgrade is recorded as a structured dict in the solver's
``stats["degradations"]`` (and warned once); when the ladder is exhausted a
``SolveError`` carrying the stage provenance and the full degradation trail
is raised.  The ladder is deliberately one-directional and monotonic: a
solve only ever gets more conservative, so a deterministic fault (e.g. a
Pallas kernel that cannot lower) is routed around in at most
``len(ladder)`` rebuilds and the result -- all rungs are numerically
equivalent pipelines -- matches the fault-free baseline.
"""
from __future__ import annotations

import os
import random
import time
import warnings
from dataclasses import dataclass

__all__ = ["SolveError", "RetryPolicy", "LADDER", "next_rung",
           "is_transient", "run_with_ladder", "reset_warn_once"]


# knob -> (from, to) downgrades, walked in priority order; one downgrade
# per failed attempt (the "step down one rung" contract)
LADDER = (
    ("engine",   (("pallas", "xla"),)),
    ("comm",     (("overlap", "pipelined"), ("fused", "pipelined"),
                  ("pipelined", "a2a"))),
    ("relayout", (("scheduled", "baseline"),)),
    ("doubling", (("deferred", "upfront"),)),
)


class SolveError(RuntimeError):
    """Terminal solve failure: the ladder is exhausted (or the error is not
    one a config downgrade can address).  Carries the failing stage, the
    final config, and the structured degradation trail."""

    def __init__(self, msg: str, *, stage=None, config=None,
                 degradations=()):
        super().__init__(msg)
        self.stage = stage
        self.config = dict(config or {})
        self.degradations = list(degradations)


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for transient failures (the whole-solve
    budget: ``retries`` attempts across all rungs).

    ``jitter="decorrelated"`` (default) draws each delay uniformly from
    ``[base_delay, 3 * previous_delay]`` capped at ``max_delay`` (the AWS
    decorrelated-jitter schedule) so co-batched tenants that trip on the
    same transient do NOT retry in lockstep; ``jitter="none"`` restores
    the fixed doubling schedule.  ``seed`` pins the jitter RNG (falling
    back to ``$REPRO_RETRY_SEED``, then entropy) for deterministic tests.
    """

    retries: int = 2
    base_delay: float = 0.05
    max_delay: float = 1.0
    jitter: str = "decorrelated"
    seed: int | None = None

    def delay_rng(self):
        if self.jitter == "none":
            return None
        seed = self.seed
        if seed is None:
            env = os.environ.get("REPRO_RETRY_SEED", "").strip()
            seed = int(env) if env else None
        return random.Random(seed)


def next_rung(cfg: dict):
    """One downgrade below ``cfg``: ``(new_cfg, action)`` or None when the
    config is already fully conservative."""
    for knob, downs in LADDER:
        cur = cfg.get(knob)
        for frm, to in downs:
            if cur == frm:
                new = dict(cfg)
                new[knob] = to
                return new, f"{knob}:{frm}->{to}"
    return None


# substrings marking an execution error as transient (retry-worthy) when it
# does not carry an explicit ``transient`` attribute -- the runtime-level
# statuses a TPU fleet surfaces for preemptions and flaky links
_TRANSIENT_MARKERS = ("RESOURCE_EXHAUSTED", "UNAVAILABLE",
                      "DEADLINE_EXCEEDED", "ABORTED")


def is_transient(e: BaseException) -> bool:
    t = getattr(e, "transient", None)
    if t is not None:
        return bool(t)
    msg = str(e)
    return any(m in msg for m in _TRANSIENT_MARKERS)


_WARNED: set = set()


def _warn_once(msg: str):
    if msg not in _WARNED:
        _WARNED.add(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=3)


def reset_warn_once():
    """Re-arm the one-shot degradation warnings (see
    ``comm.reset_warn_once`` -- same long-lived-process rationale).
    Called from ``solver.clear_solver_cache`` and the test fixtures."""
    _WARNED.clear()


def run_with_ladder(attempt, *, config: dict, reconfigure, stats: dict,
                    policy: RetryPolicy = None, describe: str = "solve",
                    diagnose=None, sleep=time.sleep):
    """Run ``attempt()`` under the degradation ladder.

    ``attempt()`` performs one full try (dispatch + optional verify) under
    the CURRENT config and raises on failure.  ``reconfigure(cfg)``
    rebuilds the solver's pipeline for ``cfg`` -- it is also invoked for
    transient retries with the unchanged config, which forces a fresh
    trace/compile (the analogue of re-establishing a collective after a
    link blip).  ``diagnose(exc)`` may return a finer stage-provenance
    string for errors that carry none.  Returns the first successful
    attempt's result; raises ``SolveError`` when the ladder is exhausted.
    """
    policy = policy or RetryPolicy()
    cfg = dict(config)
    retries_left = policy.retries
    delay = policy.base_delay
    rng = policy.delay_rng()
    records = stats.setdefault("degradations", [])
    while True:
        try:
            return attempt()
        except SolveError:
            raise
        except Exception as e:  # noqa: BLE001 -- every failure walks the ladder
            stage = getattr(e, "stage", None)
            if stage is None and diagnose is not None:
                try:
                    stage = diagnose(e)
                except Exception:  # diagnosis is best-effort
                    stage = None
            stage = stage or describe
            if is_transient(e) and retries_left > 0:
                retries_left -= 1
                stats["retries"] = stats.get("retries", 0) + 1
                _warn_once(f"{describe}: transient failure at {stage} "
                           f"({type(e).__name__}); retrying with backoff")
                sleep(delay)
                if rng is None:
                    delay = min(2.0 * delay, policy.max_delay)
                else:
                    delay = min(policy.max_delay,
                                rng.uniform(policy.base_delay,
                                            max(delay, policy.base_delay)
                                            * 3.0))
                reconfigure(dict(cfg))
                continue
            nxt = next_rung(cfg)
            if nxt is None:
                raise SolveError(
                    f"{describe}: failed at stage {stage!r} with the "
                    f"ladder exhausted (config {cfg}): {e!r}",
                    stage=stage, config=cfg, degradations=records) from e
            cfg, action = nxt
            rec = {"stage": stage, "action": action,
                   "error": f"{type(e).__name__}: {e}"[:300],
                   "config": dict(cfg)}
            records.append(rec)
            _warn_once(f"{describe}: degrading {action} after failure at "
                       f"stage {stage!r} ({type(e).__name__})")
            reconfigure(dict(cfg))
