"""Solve-as-a-service: the multi-tenant batched Poisson server
(DESIGN.md #11).

``server``   admission, per-plan-key request coalescing, deadline-bounded
             dynamic batching, the serve loop itself
``pool``     warm plan pool with memory-budget eviction
``stats``    per-tenant latency percentiles + degradation records
"""
from .server import (AdmissionError, PlanSpec, PoissonServer, ServerClosed,
                     SolveResult, default_batch_ranks)  # noqa: F401
from .pool import WarmPool  # noqa: F401
from .stats import TenantStats, percentile  # noqa: F401
