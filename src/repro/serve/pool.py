"""Warm plan pool: pre-compiled solvers for hot plan keys, evicted under a
memory budget.

The pool is the serving layer on top of ``core.solver.get_solver``: it
tracks which plan keys are hot, how many bytes each warm plan pins
(Green's function + one field workspace per compiled batch rank), and
evicts least-recently-used keys when the budget is exceeded -- including
from the module-level LRU (``evict_solver_instance``), so an evicted
plan's jit executables and Green's function actually become collectable
rather than living on behind the pool's back.

``acquire`` goes through ``get_solver``, so concurrent workers hitting a
cold key coalesce into ONE construction (the single-flight path) and a
re-acquired evicted key rebuilds transparently.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from repro.core import solver as sv

__all__ = ["WarmEntry", "WarmPool"]


@dataclass
class WarmEntry:
    solver: object
    est_bytes: int
    last_used: float
    hits: int = 0
    warmed_ranks: set = field(default_factory=set)


def _estimate_bytes(solver, ranks=()) -> int:
    """Rough resident footprint of one warm plan: the Green's function
    (the plan's dominant persistent array) plus ~3 field-sized buffers per
    compiled batch rank (input, spectral workspace, output).  An estimate
    is all eviction needs -- relative sizes order the pool correctly."""
    green = getattr(solver, "_green", None)
    if green is None:
        green = getattr(solver, "_green_raw", None)
    gbytes = int(np.asarray(green).nbytes) if green is not None else 0
    grid = int(np.prod(solver.input_shape))
    itemsize = np.dtype(getattr(solver, "dtype", np.float64)).itemsize
    per_rank = 3 * grid * itemsize
    return gbytes + per_rank * sum(max(1, r) for r in ranks)


class WarmPool:
    """LRU pool of constructed solvers under ``budget_bytes``.

    ``acquire(key, build)`` returns the cached solver for ``key`` or
    builds (and admits) it; admission evicts LRU entries until the pool
    fits the budget again.  The entry being admitted is never evicted by
    its own admission, so one plan larger than the whole budget still
    serves (the budget then only forbids *keeping* anything else)."""

    def __init__(self, budget_bytes: int | None = None):
        self.budget_bytes = budget_bytes
        self._entries: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        self.stats = {"builds": 0, "hits": 0, "evictions": 0,
                      "evicted_bytes": 0}

    def acquire(self, key, build):
        with self._lock:
            e = self._entries.get(key)
            if e is not None:
                e.hits += 1
                e.last_used = time.perf_counter()
                self._entries.move_to_end(key)
                self.stats["hits"] += 1
                return e.solver
        # build OUTSIDE the pool lock: construction is seconds of planning
        # and jit work, and get_solver's single-flight already coalesces
        # concurrent builders of the same key
        solver = build()
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                e = WarmEntry(solver, _estimate_bytes(solver),
                              time.perf_counter())
                self._entries[key] = e
                self.stats["builds"] += 1
                self._evict_over_budget(keep=key)
            else:                      # a racing admit won; use its entry
                e.hits += 1
                e.last_used = time.perf_counter()
            self._entries.move_to_end(key)
            return e.solver

    def note_rank(self, key, rank: int):
        """Record that ``key`` now holds a compiled jit for batch rank
        ``rank`` (grows the entry's footprint estimate)."""
        with self._lock:
            e = self._entries.get(key)
            if e is None or rank in e.warmed_ranks:
                return
            e.warmed_ranks.add(rank)
            e.est_bytes = _estimate_bytes(e.solver, e.warmed_ranks)
            self._evict_over_budget(keep=key)

    def warmed_ranks(self, key) -> tuple:
        with self._lock:
            e = self._entries.get(key)
            return tuple(sorted(e.warmed_ranks)) if e is not None else ()

    def _evict_over_budget(self, keep=None):
        # caller holds the lock
        if self.budget_bytes is None:
            return
        while (len(self._entries) > 1
               and self.total_bytes_locked() > self.budget_bytes):
            victim = next(k for k in self._entries if k != keep)
            e = self._entries.pop(victim)
            self.stats["evictions"] += 1
            self.stats["evicted_bytes"] += e.est_bytes
            sv.evict_solver_instance(e.solver)

    def total_bytes_locked(self) -> int:
        return sum(e.est_bytes for e in self._entries.values())

    def info(self) -> dict:
        with self._lock:
            return dict(self.stats, size=len(self._entries),
                        total_bytes=self.total_bytes_locked(),
                        budget_bytes=self.budget_bytes,
                        keys=[{"est_bytes": e.est_bytes, "hits": e.hits,
                               "ranks": sorted(e.warmed_ranks)}
                              for e in self._entries.values()])
