"""Multi-tenant batched Poisson solve server (solve-as-a-service).

The paper's dominant production operation -- the unbounded Poisson solve
-- served from a long-lived process:

    admission -> per-plan-key coalescing -> batched multi-RHS solve
              -> per-tenant response + stats

* **Admission**: ``submit`` validates the request against its plan,
  applies backpressure (bounded pending depth, ``AdmissionError``), and
  enqueues it with its arrival timestamp.  Tenants are just labels --
  isolation is by plan key, accounting by tenant.
* **Coalescing**: requests sharing a plan key are merged into ONE batched
  multi-RHS solve (PR 3: same transform count, B-fold payload).  A batch
  flushes when it reaches ``max_batch`` or when its oldest request has
  waited ``max_delay_ms`` (the latency deadline), whichever first.  The
  batch is zero-padded up to the nearest rank on the ``batch_ranks``
  ladder so a handful of jit specializations serves every occupancy
  (rows are independent through the whole pipeline, so padding never
  perturbs live results).
* **Warm pool**: constructed solvers live in a ``WarmPool`` under a
  memory budget; hot keys stay resident with their compiled batch ranks,
  cold keys are evicted (also from the module LRU) and rebuild on the
  next request through ``get_solver``'s single-flight path.
* **Resilience**: every batched solve runs under the PR-6 degradation
  ladder (``PoissonSolver.solve`` -> ``run_with_ladder``).  Ladder
  records produced by a batch are attributed to every request in it and
  surface per tenant in ``tenant_stats()``.  A request may carry its own
  ``FaultPlan`` (chaos testing): it is armed around that batch's solve
  only, and because the fault token is part of the ``get_solver`` key the
  armed batch runs on a shadow solver -- the clean warm plan's jit caches
  are never poisoned.
"""
from __future__ import annotations

import contextlib
import itertools
import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

import numpy as np
import jax.numpy as jnp

from repro.core import solver as sv
from repro.core.bc import DataLayout
from repro.core.green import GreenKind

from .pool import WarmPool
from .stats import RequestRecord, TenantStats

__all__ = ["PlanSpec", "SolveResult", "PoissonServer", "AdmissionError",
           "ServerClosed", "default_batch_ranks"]


class AdmissionError(RuntimeError):
    """Request rejected at admission (backpressure or bad shape)."""


class ServerClosed(AdmissionError):
    """Request submitted to a stopped/draining server, or failed by the
    drain deadline at shutdown.  ``queue_position`` (1-based, None for
    admission-time rejections) records where the request sat in the
    unserved queue when the deadline expired."""

    def __init__(self, msg: str, *, queue_position=None):
        super().__init__(msg)
        self.queue_position = queue_position


@dataclass(frozen=True)
class PlanSpec:
    """The serving identity of a solve: everything that selects a plan.

    Mirrors the ``get_solver`` signature; two requests coalesce into one
    batched solve iff their specs freeze to the same key.  ``mesh`` makes
    the spec distributed (a pencil solver on that mesh); ``solver_kw``
    passes through extra ``get_solver`` keywords (``comm``, ``dtype``,
    autotune knobs, ...) as a tuple of (name, value) pairs.
    """

    shape: tuple
    bcs: tuple
    L: float = 1.0
    layout: DataLayout = DataLayout.CELL
    green_kind: GreenKind = GreenKind.CHAT2
    eps_factor: float = 2.0
    engine: str = "xla"
    doubling: str = "deferred"
    relayout: str = "scheduled"
    order_policy: str = "layout"
    mesh: object = None
    solver_kw: tuple = ()
    # comm="auto" candidate policy on distributed specs (DESIGN.md #12):
    # "guided" warms the pool off the cost-model shortlist, "brute" sweeps
    search: str = "guided"

    def key(self):
        return sv._freeze((self.shape, self.L, self.bcs, self.layout,
                           self.green_kind, self.eps_factor, self.engine,
                           self.doubling, self.relayout, self.order_policy,
                           self.mesh, self.solver_kw, self.search))

    def build(self):
        kw = dict(self.solver_kw)
        if self.mesh is not None:
            kw.setdefault("autotune_search", self.search)
        return sv.get_solver(self.shape, self.L, self.bcs,
                             layout=self.layout, green_kind=self.green_kind,
                             eps_factor=self.eps_factor, engine=self.engine,
                             doubling=self.doubling, relayout=self.relayout,
                             order_policy=self.order_policy, mesh=self.mesh,
                             **kw)


@dataclass(frozen=True)
class SolveResult:
    """One response: the solution plus how the server produced it."""

    u: np.ndarray
    request_id: int
    tenant: str
    batch_size: int          # live requests in the coalesced solve
    padded_to: int           # batch rank the solve actually ran at
    queue_wait_s: float
    solve_s: float
    total_s: float
    degradations: tuple = ()
    integrity: tuple = ()    # ABFT repair/escalation records (verify="abft")


@dataclass
class _Request:
    request_id: int
    tenant: str
    f: np.ndarray
    spec: PlanSpec
    future: Future
    admit_t: float
    verify: str | None = None
    fault_plan: object = None
    # settled = response delivered (result, failure, or drain-deadline
    # ServerClosed) and the inflight count decremented -- exactly once,
    # even when a wedged worker completes after the deadline already
    # failed its batch
    settled: bool = False


@dataclass
class _Pending:
    """Per-plan-key coalescing buffer."""

    spec: PlanSpec
    requests: list = field(default_factory=list)

    @property
    def oldest_t(self):
        return self.requests[0].admit_t


def default_batch_ranks(max_batch: int) -> tuple:
    """Power-of-two jit-rank ladder up to ``max_batch`` (always includes
    ``max_batch`` itself): {1, 2, 4, ..., max_batch}."""
    ranks, r = [], 1
    while r < max_batch:
        ranks.append(r)
        r *= 2
    ranks.append(max_batch)
    return tuple(dict.fromkeys(ranks))


class PoissonServer:
    """Long-lived multi-tenant Poisson solve service.

    ``max_batch``     coalescing limit (and largest jit batch rank)
    ``max_delay_ms``  latency deadline: a pending batch never waits longer
                      than this for co-batchable traffic before flushing
    ``batch_ranks``   jit specialization ladder (default powers of two);
                      batches pad up to the nearest rank
    ``memory_budget_mb``  warm-pool budget; None = unbounded
    ``max_pending``   admission backpressure bound (pending + in-flight)
    ``workers``       solve worker threads (distinct plan keys execute
                      concurrently; one key's batches stay ordered through
                      the flush queue)
    ``drain_timeout_s``  bound on ``stop(drain=True)``: once the deadline
                      expires, every unserved request fails with
                      ``ServerClosed`` (carrying its queue position) so a
                      wedged solve can never hang shutdown.  None = wait
                      forever (the pre-deadline behaviour)

    Use as a context manager or call ``start()``/``stop()``.  ``submit``
    returns a ``concurrent.futures.Future`` resolving to ``SolveResult``.
    """

    def __init__(self, *, max_batch: int = 8, max_delay_ms: float = 2.0,
                 batch_ranks=None, memory_budget_mb=None,
                 max_pending: int = 1024, workers: int = 1,
                 verify=None, drain_timeout_s: float | None = 30.0):
        assert max_batch >= 1 and max_pending >= 1 and workers >= 1
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_ms) * 1e-3
        self.batch_ranks = tuple(sorted(batch_ranks)) if batch_ranks \
            else default_batch_ranks(self.max_batch)
        assert self.batch_ranks[-1] >= self.max_batch, (
            "batch_ranks must cover max_batch", self.batch_ranks)
        self.verify = verify
        self.drain_timeout_s = drain_timeout_s
        self.pool = WarmPool(
            None if memory_budget_mb is None
            else int(memory_budget_mb * 1e6))
        self.max_pending = int(max_pending)
        self.workers = int(workers)
        self._ids = itertools.count()
        self._cv = threading.Condition()
        self._pending: dict = {}            # key -> _Pending
        self._dispatched: dict = {}         # request_id -> _Request, flushed
        self._inflight = 0                  # admitted, not yet responded
        self._running = False
        self._draining = False
        self._flushq: queue.Queue = queue.Queue()
        self._threads: list = []
        self._tenants: dict = {}
        self._tenants_lock = threading.Lock()
        self.stats = {"admitted": 0, "rejected": 0, "completed": 0,
                      "failed": 0, "batches": 0, "deadline_flushes": 0,
                      "full_flushes": 0, "drain_flushes": 0,
                      "padded_rhs": 0, "drain_timeouts": 0}

    # -- lifecycle ---------------------------------------------------------
    def start(self):
        assert not self._running and not self._threads
        self._running = True
        self._draining = False
        t = threading.Thread(target=self._dispatch_loop,
                             name="serve-dispatch", daemon=True)
        self._threads.append(t)
        for i in range(self.workers):
            w = threading.Thread(target=self._worker_loop,
                                 name=f"serve-worker-{i}", daemon=True)
            self._threads.append(w)
        for t in self._threads:
            t.start()
        return self

    def stop(self, drain: bool = True, timeout=None):
        """Stop the server; ``drain=True`` (default) first serves every
        admitted request -- bounded by ``timeout`` (default: the
        constructor's ``drain_timeout_s``).  When the deadline expires,
        every still-unserved request fails with ``ServerClosed`` carrying
        its queue position, so one wedged solve (a stalled collective, a
        fault-armed shadow batch) cannot hang shutdown; the wedged worker
        thread is abandoned as a daemon and its late result is discarded
        by the per-request ``settled`` guard.  ``drain=False`` fails
        pending requests immediately."""
        deadline = self.drain_timeout_s if timeout is None else timeout
        with self._cv:
            if not self._running:
                return
            self._draining = True
            if not drain:
                for p in self._pending.values():
                    for r in p.requests:
                        r.settled = True
                        r.future.set_exception(
                            ServerClosed("server stopped without drain"))
                        self._request_done()
                self._pending.clear()
            self._cv.notify_all()
        # wait for the dispatcher to flush the tail, then stop the workers
        with self._cv:
            drained = self._cv.wait_for(
                lambda: not self._pending and self._inflight == 0,
                timeout=deadline)
            if not drained:
                self._fail_unserved_locked(deadline)
            self._running = False
            self._cv.notify_all()
        for _ in range(self.workers):
            self._flushq.put(None)
        join_t = None if deadline is None else max(deadline, 1.0)
        alive = []
        for t in self._threads:
            t.join(timeout=join_t)
            if t.is_alive():
                alive.append(t.name)
        self._threads.clear()
        if alive:
            with self._cv:
                self.stats["abandoned_threads"] = \
                    self.stats.get("abandoned_threads", 0) + len(alive)

    def _fail_unserved_locked(self, deadline):
        """Drain deadline expired: fail every unserved request (in-flight
        batches first, then never-flushed pending, in admission order)
        with a position-stamped ``ServerClosed``.  Caller holds the cv."""
        backlog = [r for p in self._pending.values() for r in p.requests]
        self._pending.clear()
        victims = (sorted(self._dispatched.values(),
                          key=lambda r: r.request_id)
                   + sorted(backlog, key=lambda r: r.request_id))
        victims = [r for r in victims if not r.settled]
        for pos, r in enumerate(victims, 1):
            r.settled = True
            self._dispatched.pop(r.request_id, None)
            r.future.set_exception(ServerClosed(
                f"drain deadline ({deadline}s) expired with request "
                f"{r.request_id} unserved at queue position "
                f"{pos}/{len(victims)}", queue_position=pos))
            self._tenant(r.tenant).record_failed()
            self.stats["failed"] += 1
            self.stats["drain_timeouts"] += 1
            self._request_done()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop(drain=exc == (None, None, None))
        return False

    # -- admission ---------------------------------------------------------
    def submit(self, f, spec: PlanSpec, *, tenant: str = "default",
               verify=None, fault_plan=None) -> Future:
        """Admit one solve request (a single rhs of ``spec``'s grid shape).

        Returns a future resolving to ``SolveResult``.  Raises
        ``ServerClosed`` after ``stop`` began and ``AdmissionError`` under
        backpressure (``max_pending`` admitted-but-unserved requests) or on
        a shape mismatch -- rejections are also counted per tenant.
        """
        f = np.asarray(f)
        ts = self._tenant(tenant)
        grid = tuple(spec.shape)
        want = tuple(n + (1 if spec.layout == DataLayout.NODE else 0)
                     for n in grid)
        if f.shape != want:
            ts.record_rejected()
            with self._cv:
                self.stats["rejected"] += 1
            raise AdmissionError(
                f"rhs shape {f.shape} does not match plan grid {want}")
        fut: Future = Future()
        with self._cv:
            if not self._running or self._draining:
                self.stats["rejected"] += 1
                ts.record_rejected()
                raise ServerClosed("server is not accepting requests")
            if self._inflight >= self.max_pending:
                self.stats["rejected"] += 1
                ts.record_rejected()
                raise AdmissionError(
                    f"backpressure: {self._inflight} requests in flight "
                    f"(max_pending={self.max_pending})")
            req = _Request(next(self._ids), tenant, f, spec, fut,
                           time.perf_counter(), verify=verify,
                           fault_plan=fault_plan)
            key = spec.key()
            pend = self._pending.get(key)
            if pend is None:
                pend = self._pending[key] = _Pending(spec)
            pend.requests.append(req)
            self._inflight += 1
            self.stats["admitted"] += 1
            self._cv.notify_all()
        return fut

    def solve(self, f, spec: PlanSpec, *, tenant: str = "default",
              timeout=None) -> SolveResult:
        """Blocking convenience wrapper around ``submit``."""
        return self.submit(f, spec, tenant=tenant).result(timeout=timeout)

    # -- dispatcher --------------------------------------------------------
    def _dispatch_loop(self):
        while True:
            with self._cv:
                batch = self._take_ready_locked()
                while batch is None:
                    if self._draining and not self._pending:
                        if self._inflight == 0:
                            self._cv.notify_all()
                        if not self._running:
                            return
                        self._cv.wait(0.01)
                    else:
                        self._cv.wait(self._next_deadline_locked())
                    if not self._running and not self._pending:
                        return
                    batch = self._take_ready_locked()
            self._flushq.put(batch)

    def _take_ready_locked(self):
        """Pop the first flush-ready batch: full, past its deadline, or
        the server is draining.  Caller holds the condition lock."""
        now = time.perf_counter()
        for key, pend in self._pending.items():
            full = len(pend.requests) >= self.max_batch
            aged = now - pend.oldest_t >= self.max_delay_s
            if not (full or aged or self._draining):
                continue
            take = pend.requests[:self.max_batch]
            pend.requests = pend.requests[self.max_batch:]
            if not pend.requests:
                del self._pending[key]
            for r in take:
                self._dispatched[r.request_id] = r
            self.stats["batches"] += 1
            self.stats["full_flushes" if full else
                       "drain_flushes" if self._draining and not aged else
                       "deadline_flushes"] += 1
            return key, pend.spec, take
        return None

    def _next_deadline_locked(self):
        if not self._pending:
            return None                     # sleep until notified
        now = time.perf_counter()
        oldest = min(p.oldest_t for p in self._pending.values())
        return max(1e-4, oldest + self.max_delay_s - now)

    # -- workers -----------------------------------------------------------
    def _worker_loop(self):
        while True:
            item = self._flushq.get()
            if item is None:
                return
            key, spec, reqs = item
            try:
                self._execute(key, spec, reqs)
            except BaseException as e:  # noqa: BLE001 -- fail the batch, not the server
                with self._cv:
                    fresh = [r for r in reqs if not r.settled]
                    for r in fresh:
                        r.settled = True
                        self._dispatched.pop(r.request_id, None)
                    self.stats["failed"] += len(fresh)
                    for _ in fresh:
                        self._request_done()
                for r in fresh:
                    if not r.future.done():
                        r.future.set_exception(e)
                    self._tenant(r.tenant).record_failed()

    def _execute(self, key, spec: PlanSpec, reqs):
        flush_t = time.perf_counter()
        b = len(reqs)
        rank = next(r for r in self.batch_ranks if r >= b)
        fb = np.stack([r.f for r in reqs], axis=0)
        if rank > b:                        # pad to the nearest jit rank:
            pad = np.zeros((rank - b,) + fb.shape[1:], fb.dtype)
            fb = np.concatenate([fb, pad], axis=0)
        # one armed FaultPlan per batch (chaos tests submit one faulted
        # request at a time); arming it keys get_solver to a shadow solver
        # so the clean warm plan's traces stay pristine
        plans = [r.fault_plan for r in reqs if r.fault_plan is not None]
        ctx = plans[0] if plans else contextlib.nullcontext()
        verify = next((r.verify for r in reqs if r.verify is not None),
                      self.verify)
        with ctx:
            # an armed batch bypasses the pool: the fault token in the
            # get_solver key yields a SHADOW solver, so the ladder degrades
            # (and the fault taints) that transient instance -- never the
            # clean warm plan other tenants keep hitting
            solver = spec.build() if plans \
                else self.pool.acquire(key, spec.build)
            ndeg0 = len(solver.stats["degradations"])
            nint0 = len(solver.stats.get("integrity", ()))
            t0 = time.perf_counter()
            ub = solver.solve(jnp.asarray(fb), verify=verify)
            ub = np.asarray(ub)
            solve_s = time.perf_counter() - t0
            degs = tuple(solver.stats["degradations"][ndeg0:])
            ints = tuple(solver.stats.get("integrity", ())[nint0:])
        if not plans:                       # shadow solvers are transient
            self.pool.note_rank(key, rank)
        done_t = time.perf_counter()
        with self._cv:
            fresh = {r.request_id for r in reqs if not r.settled}
            for r in reqs:
                if r.request_id in fresh:
                    r.settled = True
                    self._dispatched.pop(r.request_id, None)
            self.stats["completed"] += len(fresh)
            self.stats["padded_rhs"] += rank - b
            for _ in fresh:
                self._request_done()
        for i, r in enumerate(reqs):
            if r.request_id not in fresh:   # drain deadline beat us to it
                continue
            res = SolveResult(
                u=ub[i], request_id=r.request_id, tenant=r.tenant,
                batch_size=b, padded_to=rank,
                queue_wait_s=flush_t - r.admit_t, solve_s=solve_s,
                total_s=done_t - r.admit_t, degradations=degs,
                integrity=ints)
            self._tenant(r.tenant).record(RequestRecord(
                r.request_id, res.queue_wait_s, solve_s, res.total_s,
                b, rank, degs))
            r.future.set_result(res)

    def _request_done(self):
        # caller holds self._cv
        self._inflight -= 1
        if self._inflight == 0:
            self._cv.notify_all()

    # -- observability -----------------------------------------------------
    def _tenant(self, name: str) -> TenantStats:
        with self._tenants_lock:
            ts = self._tenants.get(name)
            if ts is None:
                ts = self._tenants[name] = TenantStats(name)
            return ts

    def tenant_stats(self) -> dict:
        with self._tenants_lock:
            tenants = list(self._tenants.values())
        return {ts.tenant: ts.summary() for ts in tenants}

    def server_stats(self) -> dict:
        with self._cv:
            out = dict(self.stats, inflight=self._inflight,
                       pending_keys=len(self._pending))
        out["pool"] = self.pool.info()
        out["solver_cache"] = sv.solver_cache_info()
        if out["batches"]:
            out["mean_batch_occupancy"] = out["completed"] / out["batches"]
        return out
