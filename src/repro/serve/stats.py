"""Per-tenant serving observability: latency percentiles + degradations.

Every completed request contributes one ``RequestRecord`` to its tenant's
``TenantStats``; ``summary()`` renders the p50/p95/p99 latency split into
queue wait vs solve time, the mean coalesced-batch occupancy, and the
degradation records the resilience ladder attributed to the tenant's
batches -- the per-tenant view of DESIGN.md #10's structured
``stats["degradations"]``.

Percentiles are nearest-rank over a bounded reservoir (the most recent
``capacity`` samples): a serve process that has handled millions of
requests keeps O(capacity) memory and the percentiles track the *current*
tail, which is what an operator watching an SLO wants.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from dataclasses import dataclass, field

__all__ = ["RequestRecord", "TenantStats", "percentile"]


def percentile(samples, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of an iterable of floats."""
    xs = sorted(samples)
    if not xs:
        return float("nan")
    rank = max(1, math.ceil(q / 100.0 * len(xs)))
    return xs[min(rank, len(xs)) - 1]


@dataclass(frozen=True)
class RequestRecord:
    """One served request, as the tenant experienced it."""

    request_id: int
    queue_wait_s: float      # admission -> batch flush
    solve_s: float           # batched solve wall time (shared by the batch)
    total_s: float           # admission -> response ready
    batch_size: int          # live requests coalesced into the solve
    padded_to: int           # jit rank the batch was padded to
    degradations: tuple = () # ladder records attributed to this batch


@dataclass
class TenantStats:
    """Bounded per-tenant accounting; thread-safe."""

    tenant: str
    capacity: int = 4096
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)
    _records: deque = field(default=None, repr=False)
    served: int = 0
    rejected: int = 0
    failed: int = 0
    degradations: list = field(default_factory=list)

    def __post_init__(self):
        if self._records is None:
            self._records = deque(maxlen=self.capacity)

    def record(self, rec: RequestRecord):
        with self._lock:
            self.served += 1
            self._records.append(rec)
            self.degradations.extend(rec.degradations)

    def record_rejected(self):
        with self._lock:
            self.rejected += 1

    def record_failed(self):
        with self._lock:
            self.failed += 1

    def summary(self) -> dict:
        with self._lock:
            recs = list(self._records)
            out = {"tenant": self.tenant, "served": self.served,
                   "rejected": self.rejected, "failed": self.failed,
                   "degradations": list(self.degradations)}
        if recs:
            total = [r.total_s for r in recs]
            out.update(
                p50_ms=percentile(total, 50) * 1e3,
                p95_ms=percentile(total, 95) * 1e3,
                p99_ms=percentile(total, 99) * 1e3,
                mean_queue_wait_ms=sum(r.queue_wait_s for r in recs)
                / len(recs) * 1e3,
                mean_solve_ms=sum(r.solve_s for r in recs) / len(recs) * 1e3,
                mean_batch_occupancy=sum(r.batch_size for r in recs)
                / len(recs),
            )
        return out
