"""AdamW from scratch (+ optional error-feedback int8 gradient compression).

The optimizer state is a pytree mirroring params: {m, v} in f32 plus the
step counter.  ``grad_compress="int8"`` quantizes gradients per-leaf with a
shared scale before the data-parallel mean and carries the quantization
error to the next step (error feedback) -- the distributed-optimization
trick for DCN-bound multi-pod training (see DESIGN.md section 6).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_compress: str = "none"     # none | int8


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    t = jnp.clip((step - cfg.warmup) /
                 jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def compress_int8(g, err):
    """Error-feedback int8 quantization of one gradient leaf."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def apply_compression(cfg: AdamWConfig, grads, err):
    if cfg.grad_compress == "none" or err is None:
        return grads, err
    out = jax.tree.map(compress_int8, grads, err)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t:
                       isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t:
                           isinstance(t, tuple))
    return deq, new_err


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 \
            else 0.0
        newp = p.astype(jnp.float32) - lr * (delta + decay)
        return newp.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
