"""Train state + train step (CE loss, AdamW, remat, optional compression)."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.comm import CommConfig
from repro.models import transformer as tf
from repro.models.common import ModelConfig, maybe_constrain
from . import optimizer as opt

import dataclasses


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    err_fb: Any          # error-feedback residuals (None unless compression)


def make_train_state(key, cfg: ModelConfig, lr=3e-4,
                     adam: opt.AdamWConfig | None = None):
    params = tf.init_params(key, cfg)
    adam = adam or opt.AdamWConfig(lr=lr)
    err = (opt.init_error_feedback(params)
           if adam.grad_compress != "none" else None)
    return TrainState(params, opt.init_opt_state(params), err)


def loss_fn(params, cfg: ModelConfig, batch, comm, mesh):
    logits, aux = tf.forward(params, cfg, batch["inputs"],
                             batch.get("frontend"), comm, mesh)
    labels = batch["labels"]
    mask = batch["mask"]
    if logits.shape[1] != labels.shape[1]:       # vlm prefix tokens
        logits = logits[:, -labels.shape[1]:]
    logits = maybe_constrain(logits, ("pod", "data"), None, "model")
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, aux


def train_step_fn(cfg: ModelConfig, adam: opt.AdamWConfig | None = None,
                  comm: CommConfig = CommConfig(), mesh=None):
    adam = adam or opt.AdamWConfig()

    def step(state: TrainState, batch):
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, cfg, batch, comm, mesh)
        grads, new_err = opt.apply_compression(adam, grads, state.err_fb)
        new_params, new_opt, om = opt.adamw_update(
            adam, state.params, grads, state.opt_state)
        metrics = {"loss": loss, **om, **aux}
        return TrainState(new_params, new_opt, new_err), metrics

    return step


def state_specs(cfg: ModelConfig, mesh_shape: dict):
    """PartitionSpec tree for the whole TrainState."""
    from jax.sharding import PartitionSpec as P
    pspec = tf.param_specs(cfg, mesh_shape)
    return TrainState(
        params=pspec,
        opt_state={"m": pspec, "v": jax.tree.map(lambda s: s, pspec),
                   "step": P()},
        err_fb=None,
    )
