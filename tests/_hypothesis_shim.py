"""Tiny deterministic stand-in for ``hypothesis`` when it isn't installed.

Provides just the surface the test suite uses -- ``given``, ``settings`` and
the ``integers`` / ``sampled_from`` / ``floats`` strategies -- by drawing a
fixed number of seeded pseudo-random examples per test.  Not a property-based
testing engine (no shrinking, no database), but it keeps the property tests
exercising real values everywhere instead of skipping whole modules.
"""
from __future__ import annotations


import random

DEFAULT_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value=None, max_value=None):
        lo = -2 ** 31 if min_value is None else min_value
        hi = 2 ** 31 - 1 if max_value is None else max_value
        return _Strategy(lambda rng: rng.randint(lo, hi))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)


def settings(max_examples=DEFAULT_EXAMPLES, deadline=None, **_kw):
    def wrap(fn):
        fn._shim_max_examples = max_examples
        return fn
    return wrap


def given(**strats):
    def wrap(fn):
        # NB: deliberately not functools.wraps -- pytest must see a
        # zero-argument test, not the wrapped signature (whose parameters
        # it would resolve as fixtures).
        def runner():
            # @settings is applied outermost at every call site, so the
            # attribute lands on `runner`; fall back to the inner fn for
            # the (unused here) given-outside-settings order.
            n = getattr(runner, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", DEFAULT_EXAMPLES))
            rng = random.Random(f"{fn.__module__}.{fn.__name__}")
            for i in range(n):
                drawn = {k: s.example(rng) for k, s in strats.items()}
                try:
                    fn(**drawn)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example (shim, draw {i}): {drawn}") from e
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return wrap
