"""Test session config.

- x64 is enabled: the solver convergence suite needs f64 to resolve
  up-to-10th-order kernels.  All model/kernel code uses explicit dtypes,
  so this does not change their behaviour.
- The device count is left at 1 (smoke tests must see one device);
  distributed tests spawn subprocesses with XLA_FLAGS themselves.
"""
import jax
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture(autouse=True)
def _reset_warn_once_state():
    """Re-arm the process-wide warn-once diagnostics around every test.

    ``comm`` and ``resilience`` deduplicate their warnings in module-global
    sets; without this reset a test asserting on a warning would pass or
    fail depending on which test warned first (execution order), and a
    ``pytest.warns`` block could see nothing at all."""
    from repro.core import comm
    from repro.runtime import resilience
    comm.reset_warn_once()
    resilience.reset_warn_once()
    yield
    comm.reset_warn_once()
    resilience.reset_warn_once()
