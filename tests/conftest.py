"""Test session config.

- x64 is enabled: the solver convergence suite needs f64 to resolve
  up-to-10th-order kernels.  All model/kernel code uses explicit dtypes,
  so this does not change their behaviour.
- The device count is left at 1 (smoke tests must see one device);
  distributed tests spawn subprocesses with XLA_FLAGS themselves.
"""
import jax

jax.config.update("jax_enable_x64", True)
