"""SDC chaos net for the ABFT guard (DESIGN.md #13).

``verify="nan"``/``"residual"`` catch non-finite or grossly wrong
solutions; a SILENT flip -- one wrong-but-finite value injected into a
transform stage, a packed collective payload, or a checkpoint leaf --
sails through both.  This net arms ``kind="flip"`` fault specs across
every pipeline stage x relayout schedule x data layout x batching and
requires the ABFT invariants to detect the corruption, attribute it to
the right stage, and repair it via selective recompute:

* detection matrix: >= 95% of fired flips detected, every detection
  attributed to the armed stage, every solve repaired to the fault-free
  baseline (xla engine; bit-exact where repair re-dispatches a
  standalone jit, roundoff-exact where the recompute branch shares the
  faulted jit);
* the two-phase guard (``verify="abft"``): the cheap end-to-end
  sandwich trips, the checked re-dispatch localizes the stage and
  repairs it inline -- no ladder degradation for a transient flip;
* clean soak: both modes, zero integrity records and zero verify
  failures over repeated randomized solves (false-positive guard);
* persistent corruption (``count=-1``) survives recompute and the
  ladder, raising a structured ``SolveError``;
* wire checksums attribute packed-payload corruption to the collective
  (transient -> the re-send path), not the surrounding compute;
* distributed (8-device subprocess): the same invariants through the
  sharded pipeline + checksum-carrying collectives, plus a multi-tenant
  serve soak where one flip-armed tenant is repaired in isolation;
* checkpoint restore: a flipped leaf fails the manifest content digest
  with ``CheckpointError`` instead of silently resuming.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from repro.core.bc import BCType, DataLayout
from repro.core.solver import PoissonSolver
from repro.runtime import abft, faults
from repro.runtime.resilience import SolveError

E, O, P, U = BCType.EVEN, BCType.ODD, BCType.PER, BCType.UNB
BCS = ((E, E), (O, E), (P, P))


def _rhs(shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


# -- invariant arithmetic ----------------------------------------------------

def test_lite_probe_axes_bounded_and_deterministic():
    qs = abft.lite_probe_axes((12, 16, 20), np.float32)
    assert [q.shape for q in qs] == [(12,), (16,), (20,)]
    for q in qs:
        assert q.dtype == np.float32
        # bounded away from zero: no site of the rank-1 outer product can
        # attenuate a corruption below 0.125x
        assert np.all((np.abs(q) >= 0.5) & (np.abs(q) <= 1.5))
    qs2 = abft.lite_probe_axes((12, 16, 20), np.float32)
    for a, b in zip(qs, qs2):
        assert np.array_equal(a, b)
    # a different grid draws a different probe
    assert not np.array_equal(
        qs[0], abft.lite_probe_axes((12, 16, 24), np.float32)[0])


def test_lite_mismatch_ab_semantics():
    assert abft.lite_mismatch_ab(1.0, 1.0, 0.0) == 0.0
    assert abft.lite_mismatch_ab(1.0, 1.1, 0.0) == pytest.approx(0.1 / 1.1)
    # the floor keeps near-cancelling dots from amplifying roundoff
    assert abft.lite_mismatch_ab(1e-9, 2e-9, 1.0) == pytest.approx(1e-9)
    # any non-finite value reads as corruption
    assert abft.lite_mismatch_ab(np.nan, 1.0, 0.0) == np.inf
    assert abft.lite_mismatch_ab([1.0, np.inf], [1.0, 1.0], 0.0) == np.inf
    # batched: worst row wins
    assert abft.lite_mismatch_ab([1.0, 2.0], [1.0, 3.0], 0.0) == \
        pytest.approx(1.0 / 3.0)


def test_verify_report_attribution_and_ledger():
    tol = 1e-8
    # repaired stage: pre-mismatch bad, post-recompute clean -> a
    # "recompute" record, no raise
    stats = {}
    recs = abft.verify_report(
        ["fwd.0", "fwd.0.post"], [1.0, 0.0], tol=tol, stats=stats)
    assert [r["action"] for r in recs] == ["recompute"]
    assert stats["integrity"][0]["stage"] == "fwd.0"
    # surviving compute mismatch -> non-transient IntegrityError
    stats = {}
    with pytest.raises(abft.IntegrityError) as ei:
        abft.verify_report(["green", "green.post"], [1.0, 1.0], tol=tol,
                           stats=stats)
    assert ei.value.stage == "verify.abft@green"
    assert not ei.value.transient
    assert stats["verify_failures"] == 1
    assert stats["integrity"][0]["action"] == "escalate"
    # wire-only mismatch -> TRANSIENT (remedy: re-send via retry path)
    with pytest.raises(abft.IntegrityError) as ei:
        abft.verify_report(["wire.comm.a2a"], [1.0], tol=tol)
    assert ei.value.transient
    assert ei.value.stage == "verify.abft@wire.comm.a2a"
    # mixed wire + compute -> NOT transient (re-sending cannot fix compute)
    with pytest.raises(abft.IntegrityError) as ei:
        abft.verify_report(
            ["wire.comm.a2a", "green", "green.post"], [1.0, 1.0, 1.0],
            tol=tol)
    assert not ei.value.transient


def test_wire_checksums_catch_slab_corruption():
    import jax.numpy as jnp
    rng = np.random.default_rng(3)
    x = rng.standard_normal((8, 6)).astype(np.float32)
    cs = abft.wire_checksums(jnp.asarray(x), 0, 4)
    assert np.allclose(np.asarray(cs),
                       x.reshape(4, 2, 6).sum(axis=(1, 2)), atol=1e-5)
    # clean round trip: no mismatch
    col = abft.Collector()
    abft.wire_verify(jnp.asarray(x), cs, 0, 4, col, "wire.comm.test", 1e-6)
    assert float(np.asarray(col.stacked())[0]) < 1e-6
    # one flipped value in the slab destined to rank 2 -> only that
    # checksum trips, and the report attributes it to the wire
    bad = x.copy()
    bad[5, 3] += 8.0 * np.abs(x).max()
    col = abft.Collector()
    abft.wire_verify(jnp.asarray(bad), cs, 0, 4, col, "wire.comm.test",
                     1e-6)
    with pytest.raises(abft.IntegrityError) as ei:
        abft.verify_report(col.names, np.asarray(col.stacked()), tol=3e-4)
    assert ei.value.transient
    assert ei.value.stage == "verify.abft@wire.comm.test"


# -- detection matrix (single process) ---------------------------------------

STAGES = ["fwd.0", "fwd.1", "fwd.2", "green", "bwd.0", "bwd.1", "bwd.2"]


def _chaos_trial(stage, *, relayout="scheduled", layout=DataLayout.CELL,
                 batched=False, verify="abft-stages", count=1):
    """Arm one flip, solve, and report (fired, detected, attributed,
    repaired) against the fault-free baseline of the same config.

    The repair baseline is the CLEAN solve under the same verify mode:
    the checked pipeline is a different jit than the plain one, so its
    healthy output differs from the plain solve at roundoff -- "repaired"
    means the recompute restored exactly what the unfaulted checked
    pipeline produces."""
    s0 = PoissonSolver((12, 12, 12), 1.0, BCS, layout=layout,
                       engine="xla", relayout=relayout)
    shape = ((2,) + s0.input_shape) if batched else s0.input_shape
    f = _rhs(shape, seed=7)
    want = np.asarray(s0.solve(f, verify=verify))
    s = PoissonSolver((12, 12, 12), 1.0, BCS, layout=layout, engine="xla",
                      relayout=relayout)
    with faults.FaultPlan([dict(kind="flip", stage=stage,
                                count=count)]) as plan:
        got = np.asarray(s.solve(f, verify=verify))
    recs = s.stats.get("integrity", [])
    detected = [r for r in recs if r["stage"].split("#")[0] == stage]
    # the recompute branch lives in the same jit as the primary apply, so
    # XLA may schedule it with different fusion: the repaired value can
    # sit one roundoff (~1e-7 rel) off the clean checked run even though
    # the injected corruption was ~0.2-0.4 rel.  "repaired" therefore
    # means equal to the clean run at roundoff -- 5+ orders of magnitude
    # below the corruption.  (The distributed test asserts strict
    # bit-exactness, where repair re-dispatches a standalone clean jit.)
    scale = float(np.max(np.abs(want)))
    err = float(np.max(np.abs(got - want)))
    return {"fired": bool(plan.log), "detected": bool(detected),
            "attributed": bool(detected),
            "repaired": err <= 1e-5 * scale,
            "degraded": bool(s.stats["degradations"]), "records": recs}


def test_sdc_detection_matrix():
    """Flips across stages x relayout schedules x CELL/NODE x batched:
    >= 95% of fired flips detected, every detection attributed to the
    armed stage, every solve repaired to the clean run WITHOUT walking
    the degradation ladder (inline selective recompute is the remedy)."""
    matrix = [dict(stage=st, relayout=rl)
              for st in STAGES for rl in ("scheduled", "baseline")]
    matrix += [dict(stage=st, layout=DataLayout.NODE)
               for st in ("fwd.0", "green", "bwd.2")]
    matrix += [dict(stage=st, batched=True)
               for st in ("fwd.1", "green", "bwd.0")]
    fired, hits = 0, 0
    for case in matrix:
        r = _chaos_trial(**case)
        assert r["fired"], f"flip never fired: {case}"
        fired += 1
        if r["detected"]:
            hits += 1
            assert r["attributed"], (case, r["records"])
        assert r["repaired"], (case, r["records"])
        assert not r["degraded"], (case, "recompute must not degrade")
    assert hits / fired >= 0.95, f"detected {hits}/{fired}"


def test_two_phase_guard_localizes_then_repairs():
    """``verify="abft"``: the cheap sandwich runs on every solve; a flip
    trips it (hit 1 lands in the sandwich trace), the checked re-dispatch
    localizes the stage (hit 2), and the inline recompute repairs it --
    transient SDC never reaches the degradation ladder."""
    s0 = PoissonSolver((12, 12, 12), 1.0, BCS, engine="xla")
    f = _rhs(s0.input_shape)
    # after the trip the answer comes from the checked re-dispatch, so
    # the bit-exact baseline is the clean CHECKED pipeline's output
    want = np.asarray(s0.solve(f, verify="abft-stages"))
    s = PoissonSolver((12, 12, 12), 1.0, BCS, engine="xla", verify="abft")
    with faults.FaultPlan([dict(kind="flip", stage="fwd.1",
                                count=2)]) as plan:
        got = np.asarray(s.solve(f))
    assert len(plan.log) == 2, plan.log
    recs = s.stats["integrity"]
    assert recs[0]["stage"] == "solve.linearity"
    assert recs[0]["action"] == "localize"
    assert any(r["stage"].split("#")[0] == "fwd.1"
               and r["action"] == "recompute" for r in recs[1:]), recs
    assert s.stats["verify_failures"] == 1
    assert not s.stats["degradations"]
    # equal to the clean checked run at roundoff (see _chaos_trial note)
    assert float(np.max(np.abs(got - want))) <= \
        1e-5 * float(np.max(np.abs(want)))


def test_clean_soak_zero_false_positives():
    """Randomized clean solves under both guard modes: not a single
    integrity record or verify failure may appear (tolerances must sit
    above the roundoff of every healthy config)."""
    for verify in ("abft", "abft-stages"):
        s = PoissonSolver((16, 16, 16), 1.0, BCS, engine="xla",
                          verify=verify)
        ref = PoissonSolver((16, 16, 16), 1.0, BCS, engine="xla")
        for seed in range(8):
            f = _rhs(s.input_shape, seed=seed)
            got = np.asarray(s.solve(f))
            assert np.allclose(got, np.asarray(ref.solve(f)),
                               atol=1e-4, rtol=1e-4)
        assert s.stats["verify_failures"] == 0, verify
        assert not s.stats.get("integrity"), (verify, s.stats["integrity"])
        assert not s.stats["degradations"]


def test_persistent_corruption_escalates_to_solve_error():
    """``count=-1``: the flip re-fires on every recompute and every
    ladder rung's retrace -- the guard must escalate to a structured
    ``SolveError`` with ABFT stage provenance, never return silently
    corrupted output."""
    s = PoissonSolver((12, 12, 12), 1.0, BCS, engine="xla",
                      verify="abft-stages")
    f = _rhs(s.input_shape)
    with faults.FaultPlan([dict(kind="flip", stage="green", count=-1)]):
        with pytest.raises(SolveError) as ei:
            s.solve(f)
    assert ei.value.stage == "verify.abft@green"
    ledger = s.stats["integrity"]
    assert any(r["action"] == "escalate" and r["stage"] == "green"
               for r in ledger), ledger
    # the ladder walked its rungs before giving up
    assert [d["action"] for d in ei.value.degradations] == \
        ["relayout:scheduled->baseline", "doubling:deferred->upfront"]


# -- checkpoint content digests ----------------------------------------------

def test_checkpoint_flip_on_restore_raises(tmp_path):
    d = str(tmp_path)
    tree = {"w": np.arange(12.0).reshape(4, 3), "b": np.ones(5)}
    ck.save(d, 0, tree)
    # storage rot between save and restore: one flipped value in leaf 1
    # is shape/dtype/finite-valid -- only the content digest can see it
    with faults.FaultPlan([dict(kind="flip", stage="ckpt.leaf.1")]) as plan:
        with pytest.raises(ck.CheckpointError, match="digest"):
            ck.restore(d, 0, tree)
    assert plan.log, "restore taint never fired"
    # the same checkpoint restores clean without the armed plan
    out = ck.restore(d, 0, tree)
    assert np.array_equal(out["w"], tree["w"])
    assert np.array_equal(out["b"], tree["b"])


def test_checkpoint_digest_recorded_per_leaf(tmp_path):
    import json
    d = str(tmp_path)
    ck.save(d, 0, {"w": np.full((3, 3), 2.0)})
    with open(os.path.join(d, "step_0", "manifest.json")) as fh:
        man = json.load(fh)
    assert all(len(ent["crc32"]) == 8 for ent in man["leaves"])
    # rot the bytes on disk directly: restore must refuse
    path = os.path.join(d, "step_0", "arr_0.npy")
    arr = np.load(path)
    arr[1, 1] += 1.0
    np.save(path, arr)
    with pytest.raises(ck.CheckpointError, match="digest"):
        ck.restore(d, 0, {"w": np.zeros((3, 3))})


# -- distributed chaos (8-device subprocess) ---------------------------------

_DIST_SDC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver
from repro.runtime import faults, resilience

P, U = BCType.PER, BCType.UNB
mesh = jax.make_mesh((2, 4), ("data", "model"))
n = 16
f = np.random.default_rng(0).standard_normal((n, n, n)).astype(np.float32)

for bcs, comm in ((((P, P),) * 3, CommConfig("a2a")),
                  (((U, U), (P, P), (U, U)), CommConfig("pipelined", 2))):
    s = DistributedPoissonSolver((n, n, n), 1.0, bcs, mesh=mesh, comm=comm,
                                 engine="xla", verify="abft")
    want = np.asarray(s.solve(f))
    # clean guard run: bit-exact vs verify-off (same jit), no records
    s_off = DistributedPoissonSolver((n, n, n), 1.0, bcs, mesh=mesh,
                                     comm=comm, engine="xla")
    assert np.array_equal(want, np.asarray(s_off.solve(f)))
    assert not s.stats.get("integrity"), s.stats
    # transform-stage flip: sandwich trips (hit 1), checked re-dispatch
    # localizes fwd.0 (hit 2), inline recompute repairs -- bit-exact, no
    # ladder degradation
    with faults.FaultPlan([dict(kind="flip", stage="fwd.0",
                                count=2)]) as plan:
        got = np.asarray(s.solve(f))
    assert len(plan.log) == 2, plan.log
    recs = s.stats["integrity"]
    assert recs[0]["stage"] == "solve.linearity", recs
    assert recs[0]["action"] == "localize", recs
    assert any(r["stage"].split("#")[0] == "fwd.0"
               and r["action"] == "recompute" for r in recs[1:]), recs
    assert np.array_equal(got, want), "selective recompute not bit-exact"
    assert not s.stats["degradations"], s.stats["degradations"]
    # wire flip in a packed collective payload: the sandwich detects it,
    # the re-dispatch (a fresh trace = a re-send) comes back clean
    s.stats["integrity"] = []
    with faults.FaultPlan([dict(kind="flip", stage="comm.wire.*",
                                count=1)]) as plan:
        got = np.asarray(s.solve(f))
    assert plan.log, "wire flip never fired"
    assert any(r["stage"] == "solve.linearity"
               for r in s.stats["integrity"])
    assert np.array_equal(got, want)

# wire ATTRIBUTION under the always-checked mode: the receive-side
# checksum row blames the collective (kind="wire"), and recovery goes
# through the transient/ladder path rather than silent acceptance
s = DistributedPoissonSolver((n, n, n), 1.0, ((P, P),) * 3, mesh=mesh,
                             comm=CommConfig("a2a"), engine="xla",
                             verify="abft-stages")
want = np.asarray(s.solve(f))
scale = float(np.max(np.abs(want)))
with faults.FaultPlan([dict(kind="flip", stage="comm.wire.*",
                            count=1)]) as plan:
    got = np.asarray(s.solve(f))
assert plan.log, "wire flip never fired"
wire_recs = [r for r in s.stats["integrity"] if r["kind"] == "wire"]
assert wire_recs and all(r["stage"].startswith("wire.")
                         for r in wire_recs), s.stats["integrity"]
assert float(np.max(np.abs(got - want))) <= 1e-5 * scale

# persistent distributed corruption: every retrace re-fires -> SolveError
s = DistributedPoissonSolver((n, n, n), 1.0, ((P, P),) * 3, mesh=mesh,
                             comm=CommConfig("a2a"), engine="xla",
                             verify="abft-stages")
try:
    with faults.FaultPlan([dict(kind="flip", stage="green", count=-1)]):
        s.solve(f)
    raise SystemExit("expected SolveError")
except resilience.SolveError as e:
    assert e.stage == "verify.abft@green", e.stage
print("OK dist-sdc")
"""


_SERVE_SOAK_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.runtime import faults
from repro.serve import PlanSpec, PoissonServer

P = BCType.PER
mesh = jax.make_mesh((2, 4), ("data", "model"))
n = 16
spec = PlanSpec(shape=(n, n, n), bcs=((P, P),) * 3, mesh=mesh,
                solver_kw=(("comm", CommConfig("a2a")),))
rng = np.random.default_rng(0)
fields = [rng.standard_normal((n, n, n)).astype(np.float32)
          for _ in range(4)]

with PoissonServer(max_batch=4, max_delay_ms=1.0, verify="abft") as srv:
    # clean baseline per field through the warm plan
    base = [srv.solve(f, spec, tenant="warm") for f in fields]
    assert all(not r.integrity for r in base)
    # one flip-armed tenant: its request runs on a SHADOW solver (the
    # fault token keys get_solver), gets localized + repaired, and the
    # co-resident clean tenants keep getting pristine bit-exact answers
    plan = faults.FaultPlan([dict(kind="flip", stage="fwd.0", count=2)])
    bad_fut = srv.submit(fields[0], spec, tenant="chaos", fault_plan=plan)
    bad = bad_fut.result(timeout=120)
    stages = [r["stage"] for r in bad.integrity]
    assert "solve.linearity" in stages, bad.integrity
    assert any(s.split("#")[0] == "fwd.0" for s in stages), bad.integrity
    assert np.array_equal(bad.u, base[0].u), "faulted tenant not repaired"
    # soak the clean tenants after the chaos request: zero integrity
    # records, bit-exact vs the pre-chaos baseline
    for t in range(6):
        for i, f in enumerate(fields):
            r = srv.solve(f, spec, tenant=f"t{t}")
            assert not r.integrity, r.integrity
            assert not r.degradations, r.degradations
            assert np.array_equal(r.u, base[i].u), (t, i)
print("OK serve-soak")
"""


def _run_sub(script, *argv, env_extra=None):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_COMM_CACHE", None)
    env.pop("REPRO_FAULTS", None)
    env.update(env_extra or {})
    out = subprocess.run([sys.executable, "-c", script, *argv],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out


def test_distributed_sdc_chaos():
    out = _run_sub(_DIST_SDC_SCRIPT)
    assert "OK dist-sdc" in out.stdout


@pytest.mark.slow
def test_serve_soak_flip_armed_tenant_isolated():
    out = _run_sub(_SERVE_SOAK_SCRIPT)
    assert "OK serve-soak" in out.stdout
