"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts shapes and finiteness."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import LM_ARCHS, get_smoke
from repro.models import transformer as tf
from repro.training.train_step import make_train_state, train_step_fn
from repro.data.pipeline import synthetic_batch

B, S = 2, 32


def _frontend(cfg, batch):
    if cfg.n_frontend_tokens:
        rng = np.random.default_rng(0)
        return jnp.asarray(rng.standard_normal(
            (batch, cfg.n_frontend_tokens, cfg.d_model)), jnp.float32)
    return None


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_finite(arch):
    cfg = get_smoke(arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab, (B, S)), jnp.int32)
    logits, aux = jax.jit(
        lambda p, t, f: tf.forward(p, cfg, t, f))(
            params, tokens, _frontend(cfg, B))
    s_total = S + (cfg.n_frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, s_total, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step(arch):
    cfg = get_smoke(arch)
    state = make_train_state(jax.random.PRNGKey(0), cfg, lr=1e-3)
    step = train_step_fn(cfg)
    batch = synthetic_batch(cfg, 0, B, S)
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    l0 = jax.tree.leaves(state.params)[0]
    l1 = jax.tree.leaves(state2.params)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))
    # and a second step works (optimizer state is consistent)
    state3, m3 = jax.jit(step)(state2, synthetic_batch(cfg, 1, B, S))
    assert np.isfinite(float(m3["loss"]))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_step(arch):
    cfg = get_smoke(arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    caches = tf.init_caches(cfg, B, max_len=S)
    tok = jnp.zeros((B, 1), jnp.int32)
    fn = jax.jit(lambda p, t, c, pos: tf.decode_step(p, cfg, t, c, pos))
    logits, caches = fn(params, tok, caches, 0)
    logits, caches = fn(params, tok, caches, 1)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-2.7b",
                                  "recurrentgemma-9b", "starcoder2-7b"])
def test_decode_matches_forward(arch):
    """Autoregressive decode logits == full-forward logits (same tokens)."""
    cfg = get_smoke(arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full, _ = jax.jit(lambda p, t: tf.forward(p, cfg, t))(params, tokens)

    caches = tf.init_caches(cfg, B, max_len=S)
    fn = jax.jit(lambda p, t, c, pos: tf.decode_step(p, cfg, t, c, pos))
    outs = []
    for i in range(S):
        lg, caches = fn(params, tokens[:, i:i + 1], caches, i)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def test_prefill_then_decode_matches(arch="qwen3-0.6b"):
    """prefill caches + one decode == forward at the next position."""
    cfg = get_smoke(arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    _, caches = jax.jit(lambda p, t: tf.prefill(p, cfg, t, max_len=S + 4))(
        params, tokens)
    nxt = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    lg_dec, _ = jax.jit(
        lambda p, t, c: tf.decode_step(p, cfg, t, c, S))(params, nxt, caches)
    full, _ = jax.jit(lambda p, t: tf.forward(p, cfg, t))(
        params, jnp.concatenate([tokens, nxt], axis=1))
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-2, atol=2e-2)
