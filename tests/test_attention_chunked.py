"""Chunked (flash-style) attention == naive attention, all mask modes."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import attention as attn
from repro.models import transformer as tf


def _run(cfg, s=64, b=2, prefix_len=0, causal=True):
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda a: a[0],
                     params["layers"])["attn"]  # first layer's attention
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    naive = attn.attention(p, dataclasses.replace(cfg, attn_block=0),
                           x, pos, causal=causal, prefix_len=prefix_len)
    chunked = attn.attention(p, dataclasses.replace(cfg, attn_block=16),
                             x, pos, causal=causal, prefix_len=prefix_len)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(naive),
                               rtol=2e-2, atol=2e-2)


def test_chunked_causal():
    _run(get_smoke("qwen3-0.6b"))


def test_chunked_sliding_window():
    _run(get_smoke("starcoder2-7b"))  # window=16 in the smoke config


def test_chunked_prefix_lm():
    _run(get_smoke("paligemma-3b"), prefix_len=12)


def test_chunked_uneven_blocks():
    _run(get_smoke("qwen3-0.6b"), s=50)  # 50 % 16 != 0
