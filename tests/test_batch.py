"""Batched multi-RHS execution + the global plan cache.

Property-based (hypothesis, scipy-free): ``solve((B, *grid))`` must equal
the stack of B single solves to last-ulp tolerance (the batched pipeline
runs the same transform sequence over bigger row batches -- no
reassociation in our code; the tolerance only allows a backend FFT to
dispatch batched rows to a differently-rounded kernel), for random batch
sizes, BC mixes, layouts and Green kinds on both engines.  Plus unit
tests for the ``get_solver`` LRU: hits, eviction order, capacity, and
distinct keys.
"""
import numpy as np
import pytest
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI images without hypothesis: deterministic local shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.bc import BCType, DataLayout
from repro.core.biot_savart import BiotSavartSolver
from repro.core.green import GreenKind
from repro.core import solver as sv
from repro.core.solver import (PoissonSolver, get_solver,
                               clear_solver_cache, solver_cache_info,
                               set_solver_cache_capacity)

E, O, P, U = BCType.EVEN, BCType.ODD, BCType.PER, BCType.UNB

# one direction's BC pair: symmetric, periodic, unbounded and semi mixes
DIR_BCS = [(E, E), (O, E), (O, O), (P, P), (U, U), (U, E), (O, U)]


def _stacked_reference(s, fb):
    return np.stack([np.asarray(s.solve(fb[i])) for i in range(fb.shape[0])])


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=4),
    bc0=st.sampled_from(DIR_BCS), bc1=st.sampled_from(DIR_BCS),
    bc2=st.sampled_from(DIR_BCS),
    layout=st.sampled_from([DataLayout.CELL, DataLayout.NODE]),
    green=st.sampled_from([GreenKind.CHAT2, GreenKind.HEJ2]),
    seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
)
def test_batched_solve_equals_stacked_xla(b, bc0, bc1, bc2, layout, green,
                                          seed):
    n = 8
    s = get_solver((n, n, n), 1.0, (bc0, bc1, bc2), layout=layout,
                   green_kind=green)
    rng = np.random.default_rng(seed)
    fb = rng.standard_normal((b,) + s.input_shape)
    want = _stacked_reference(s, fb)
    got = np.asarray(s.solve(fb))
    # identical op sequence over bigger row batches; tolerance only covers
    # backend FFTs that round batched rows differently (bit-exact on CPU)
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=1e-13)


@settings(max_examples=4, deadline=None)
@given(
    b=st.integers(min_value=2, max_value=3),
    bc0=st.sampled_from([(E, E), (U, U), (P, P)]),
    layout=st.sampled_from([DataLayout.CELL, DataLayout.NODE]),
    seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
)
def test_batched_solve_equals_stacked_pallas(b, bc0, layout, seed):
    n = 8
    s = get_solver((n, n, n), 1.0, (bc0, (O, E), (P, P)), layout=layout,
                   engine="pallas")
    rng = np.random.default_rng(seed)
    fb = rng.standard_normal((b,) + s.input_shape)
    want = _stacked_reference(s, fb)
    got = np.asarray(s.solve(fb))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_batched_rejects_bad_ranks():
    s = PoissonSolver((8, 8, 8), 1.0, ((E, E), (E, E), (E, E)))
    with pytest.raises(AssertionError):
        s.solve(np.zeros((8, 8)))               # rank too low
    with pytest.raises(AssertionError):
        s.solve(np.zeros((2, 2, 8, 8, 8)))      # two batch axes
    with pytest.raises(AssertionError):
        s.solve(np.zeros((2, 8, 8, 9)))         # wrong grid


def test_batched_biot_savart_uniform_plans():
    """Uniform-BC Biot-Savart runs the single batched 3-component pipeline
    and matches the sequential per-component implementation."""
    import jax
    n = 8
    UU = [(U, U)] * 3
    s = BiotSavartSolver((n, n, n), 1.0, [UU, UU, UU],
                         layout=DataLayout.NODE)
    assert s.batched
    rng = np.random.default_rng(0)
    f = rng.standard_normal(s.input_shape)
    got = np.asarray(s.solve(f))
    want = np.asarray(jax.jit(s._solve_impl)(jnp.asarray(f)))
    np.testing.assert_allclose(got, want, rtol=1e-13, atol=1e-13)


def test_non_uniform_biot_savart_stays_sequential():
    BCS = [[(U, U), (U, U), (O, O)],
           [(U, U), (U, U), (O, O)],
           [(U, U), (U, U), (E, E)]]
    s = BiotSavartSolver((8, 8, 8), 1.0, BCS, layout=DataLayout.NODE)
    assert not s.batched


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

@pytest.fixture
def fresh_cache():
    clear_solver_cache()
    old = solver_cache_info()["capacity"]
    yield
    set_solver_cache_capacity(old)
    clear_solver_cache()


def test_plan_cache_hit_returns_same_instance(fresh_cache):
    kw = dict(layout=DataLayout.CELL, green_kind=GreenKind.CHAT2)
    s1 = get_solver((8, 8, 8), 1.0, ((E, E), (E, E), (E, E)), **kw)
    s2 = get_solver((8, 8, 8), 1.0, ((E, E), (E, E), (E, E)), **kw)
    assert s1 is s2
    info = solver_cache_info()
    assert info["hits"] == 1 and info["misses"] == 1 and info["size"] == 1


def test_plan_cache_distinct_keys_miss(fresh_cache):
    base = ((8, 8, 8), 1.0, ((E, E), (E, E), (E, E)))
    s0 = get_solver(*base)
    variants = [
        get_solver((8, 8, 9), 1.0, ((E, E), (E, E), (E, E))),
        get_solver((8, 8, 8), 2.0, ((E, E), (E, E), (E, E))),
        get_solver((8, 8, 8), 1.0, ((O, O), (E, E), (E, E))),
        get_solver(*base, layout=DataLayout.NODE),
        get_solver(*base, green_kind=GreenKind.HEJ2),
        get_solver(*base, eps_factor=3.0),
        get_solver(*base, engine="pallas"),
    ]
    assert all(v is not s0 for v in variants)
    assert len({id(v) for v in variants}) == len(variants)
    assert solver_cache_info()["misses"] == 1 + len(variants)
    assert solver_cache_info()["hits"] == 0


def test_plan_cache_lru_eviction(fresh_cache):
    set_solver_cache_capacity(2)
    bcs = ((E, E), (E, E), (E, E))
    s_a = get_solver((8, 8, 8), 1.0, bcs)
    s_b = get_solver((8, 8, 9), 1.0, bcs)
    # touch A so B is the least recently used
    assert get_solver((8, 8, 8), 1.0, bcs) is s_a
    s_c = get_solver((8, 8, 10), 1.0, bcs)         # evicts B
    info = solver_cache_info()
    assert info["size"] == 2 and info["evictions"] == 1
    assert get_solver((8, 8, 8), 1.0, bcs) is s_a  # A survived
    assert get_solver((8, 8, 10), 1.0, bcs) is s_c
    assert get_solver((8, 8, 9), 1.0, bcs) is not s_b   # B was evicted


def test_plan_cache_capacity_shrink_evicts(fresh_cache):
    set_solver_cache_capacity(4)
    bcs = ((E, E), (E, E), (E, E))
    for k in range(4):
        get_solver((8, 8, 8 + k), 1.0, bcs)
    assert solver_cache_info()["size"] == 4
    set_solver_cache_capacity(1)
    info = solver_cache_info()
    assert info["size"] == 1 and info["evictions"] == 3
    # the survivor is the most recently used entry
    assert solver_cache_info()["hits"] == 0
    get_solver((8, 8, 11), 1.0, bcs)
    assert solver_cache_info()["hits"] == 1


def test_plan_cache_solver_still_correct(fresh_cache):
    """Cache round trip must not corrupt the solver: cached instance
    reproduces a freshly constructed solver's output exactly."""
    bcs = ((E, E), (O, E), (P, P))
    s_cached = get_solver((8, 8, 8), 1.0, bcs)
    s_cached2 = get_solver((8, 8, 8), 1.0, bcs)
    fresh = PoissonSolver((8, 8, 8), 1.0, bcs)
    rng = np.random.default_rng(3)
    f = rng.standard_normal(fresh.input_shape)
    np.testing.assert_allclose(np.asarray(s_cached2.solve(f)),
                               np.asarray(fresh.solve(f)),
                               rtol=1e-13, atol=1e-13)
    assert s_cached is s_cached2


# ---------------------------------------------------------------------------
# single-flight construction (the serve thundering herd)
# ---------------------------------------------------------------------------

def test_single_flight_one_construction_per_key(fresh_cache, monkeypatch):
    """16 threads missing the same key concurrently must construct the
    solver exactly ONCE (the others park on the builder and receive the
    same instance); before the single-flight fix the miss path built
    outside the lock, so every thread paid plan+autotune+jit and the last
    insert silently overwrote its 15 siblings."""
    import threading

    built = []
    build_gate = threading.Barrier(16, timeout=60)
    real = sv.PoissonSolver

    class Counting(real):
        def __init__(self, *a, **kw):
            built.append(threading.get_ident())
            super().__init__(*a, **kw)

    monkeypatch.setattr(sv, "PoissonSolver", Counting)
    bcs = ((E, E), (O, E), (P, P))
    out, errors = [], []

    def worker():
        try:
            build_gate.wait()               # maximize miss concurrency
            out.append(get_solver((8, 8, 8), 1.0, bcs))
        except Exception as e:  # noqa: BLE001 -- surfaced by the assert
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert len(built) == 1, f"{len(built)} constructions for one key"
    assert len(out) == 16 and all(s is out[0] for s in out)
    info = solver_cache_info()
    assert info["misses"] == 1
    # a thread that arrives while the build is in flight parks (coalesced);
    # one that arrives after it landed is a plain hit -- either way no
    # second construction happened
    assert info["coalesced"] + info["hits"] == 15


def test_single_flight_failed_build_reraises_everywhere(fresh_cache,
                                                        monkeypatch):
    """A failed construction must re-raise in the builder AND every parked
    waiter, and leave no cache entry (the next call retries cleanly)."""
    import threading

    calls = []
    real = sv.PoissonSolver

    class Flaky(real):
        def __init__(self, *a, **kw):
            calls.append(1)
            if len(calls) == 1:
                raise RuntimeError("flaky plan-time failure")
            super().__init__(*a, **kw)

    monkeypatch.setattr(sv, "PoissonSolver", Flaky)
    bcs = ((E, E), (E, E), (E, E))
    gate = threading.Barrier(4, timeout=60)
    failures = []

    def worker():
        gate.wait()
        try:
            get_solver((8, 8, 8), 1.0, bcs)
        except RuntimeError:
            failures.append(1)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # exactly one attempt ran (single-flight), and every thread that
    # joined that build saw its failure; late arrivals may have retried
    # and succeeded -- both outcomes are valid, the cache must just not
    # hold a broken entry
    assert failures, "no thread observed the injected build failure"
    assert solver_cache_info()["build_failures"] == 1
    s = get_solver((8, 8, 8), 1.0, bcs)    # clean retry after the failure
    assert s is get_solver((8, 8, 8), 1.0, bcs)
