"""Biot-Savart solver validation: the vortex tube of paper section V."""
import numpy as np
import pytest
from scipy.special import expn

from repro.core.bc import BCType, DataLayout
from repro.core.biot_savart import BiotSavartSolver
from repro.core.green import GreenKind

E, O, U = BCType.EVEN, BCType.ODD, BCType.UNB
L = 1.0
R = 0.3 * L
E2_1 = expn(2, 1.0)

# vorticity BCs: unbounded x/y; z: w_x, w_y odd, w_z even (paper section V)
BCS = [
    [(U, U), (U, U), (O, O)],
    [(U, U), (U, U), (O, O)],
    [(U, U), (U, U), (E, E)],
]


def tube_fields(n, layout=DataLayout.NODE):
    h = L / n
    x1 = np.arange(n + 1) * h if layout == DataLayout.NODE else \
        (np.arange(n) + 0.5) * h
    x, y, z = np.meshgrid(x1, x1, x1, indexing="ij")
    dx, dy = x - 0.5 * L, y - 0.5 * L
    r = np.hypot(dx, dy)
    s2 = (r / R) ** 2
    inside = s2 < 0.999999
    s2c = np.where(inside, s2, 0.0)
    wz = np.where(
        inside,
        (1.0 / (2.0 * np.pi)) * (2.0 / R**2) / E2_1
        * np.exp(-1.0 / (1.0 - s2c)),
        0.0)
    f = np.stack([np.zeros_like(wz), np.zeros_like(wz), -wz])

    # analytic velocity: u_theta = 1/(2 pi r) [1 - (1-s2) E2(1/(1-s2))/E2(1)]
    rs = np.where(r > 1e-12, r, 1.0)
    with np.errstate(over="ignore"):
        arg = 1.0 / np.where(inside, 1.0 - s2c, 1.0)
    bracket = np.where(inside, 1.0 - (1.0 - s2c) * expn(2, arg) / E2_1, 1.0)
    utheta = bracket / (2.0 * np.pi * rs)
    utheta = np.where(r > 1e-12, utheta, 0.0)
    ux = -dy / rs * utheta
    uy = dx / rs * utheta
    ux = np.where(r > 1e-12, ux, 0.0)
    uy = np.where(r > 1e-12, uy, 0.0)
    u = np.stack([ux, uy, np.zeros_like(ux)])
    return f, u


def linf(n, green, fd_order=0, layout=DataLayout.NODE):
    f, u_ref = tube_fields(n, layout)
    s = BiotSavartSolver((n, n, n), L, BCS, layout=layout,
                         green_kind=green, fd_order=fd_order)
    u = np.asarray(s.solve(f.astype(np.float64)))
    return np.max(np.abs(u - u_ref))


@pytest.mark.parametrize("green,fd,order,ns", [
    (GreenKind.CHAT2, 0, 2.0, (32, 64)),  # spectral diff, kernel order 2
    # HEJ4: kernel order 4; the bump's wide spectrum keeps (k eps)^4 large
    # until n ~ O(100) -- we assert the order is clearly past 2nd and rising
    # (2.5 -> 2.7 -> 3.0 measured at 32/48/64/96), paper Fig 9 regime
    (GreenKind.HEJ4, 0, 3.4, (48, 96)),
    (GreenKind.HEJ4, 2, 2.0, (32, 64)),   # FD2 limits the order (Fig 18)
    (GreenKind.HEJ2, 6, 2.0, (32, 64)),   # kernel limits the order (Fig 10)
])
def test_vortex_tube_orders(green, fd, order, ns):
    errs = [linf(n, green, fd) for n in ns]
    p = np.log(errs[0] / errs[1]) / np.log(ns[1] / ns[0])
    assert p > order - 0.6, (p, errs)


def test_vortex_tube_cell_layout():
    err = linf(48, GreenKind.CHAT2, 0, DataLayout.CELL)
    assert err < 4e-3, err


def test_incompatible_bcs_raise():
    bad = [row[:] for row in BCS]
    bad[0][2] = (E, E)  # w_x even in z clashes with w_y odd
    with pytest.raises(ValueError):
        BiotSavartSolver((16, 16, 16), L, bad)
