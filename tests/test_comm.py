"""Unit tests for the CommStrategy classes and the plan-time autotuner
(single process; the multi-device equivalence runs live in
tests/test_distributed.py)."""
import numpy as np
import pytest

from repro.core import comm as cm
from repro.core.comm import (CommConfig, as_comm, autotune_candidates,
                             autotune_comm, clear_autotune_cache,
                             make_strategy)
from repro.launch.hlo_stats import comm_interleave_stats


# -- config parsing ---------------------------------------------------------

def test_strategies_registry_complete():
    assert set(cm.STRATEGIES) == {"a2a", "pipelined", "fused", "overlap"}
    for name in cm.STRATEGIES:
        strat = make_strategy(CommConfig(name, 3))
        assert strat.name == name
        assert strat.n_chunks == 3


def test_comm_config_rejects_unknown_strategy():
    with pytest.raises(AssertionError):
        CommConfig("allgather")
    with pytest.raises(AssertionError):
        CommConfig("a2a", 0)


def test_as_comm_accepts_name_config_and_none():
    assert as_comm(None) == CommConfig()
    assert as_comm("overlap") == CommConfig("overlap")
    cfg = CommConfig("pipelined", 8)
    assert as_comm(cfg) is cfg


# -- chunk padding (the silent-fallback fix) --------------------------------

def test_split_chunks_pads_non_dividing_axis_and_warns_once():
    import jax.numpy as jnp
    x = jnp.arange(2 * 7 * 3, dtype=jnp.float32).reshape(2, 7, 3)
    cm._WARNED.clear()
    with pytest.warns(RuntimeWarning, match="zero-padding"):
        chunks, ln = cm._split_chunks(x, 1, 2)
    assert ln == 7
    assert [c.shape for c in chunks] == [(2, 4, 3), (2, 4, 3)]
    merged = jnp.concatenate(chunks, axis=1)
    np.testing.assert_array_equal(np.asarray(merged[:, :7]), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(merged[:, 7:]), 0.0)
    # second occurrence of the same shape is silent (warn once)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cm._split_chunks(x, 1, 2)


def test_split_chunks_exact_division_no_pad():
    import jax.numpy as jnp
    x = jnp.ones((2, 8, 3))
    chunks, ln = cm._split_chunks(x, 1, 4)
    assert ln == 8 and len(chunks) == 4
    assert all(c.shape == (2, 2, 3) for c in chunks)


# -- autotuner --------------------------------------------------------------

def test_autotune_candidates_sweep():
    cands = autotune_candidates(max_chunks=4)
    labels = {(c.strategy, c.n_chunks) for c in cands}
    assert ("a2a", 1) in labels and ("fused", 1) in labels
    assert ("pipelined", 2) in labels and ("overlap", 4) in labels
    assert all(isinstance(c, CommConfig) for c in cands)


def test_autotune_picks_fastest_and_caches_in_memory():
    clear_autotune_cache()
    calls = []

    def fake_time(cfg):
        calls.append(cfg)
        return 0.001 if cfg == CommConfig("overlap", 4) else 0.01

    res = {}
    best = autotune_comm(("k1",), fake_time, cache_path="", results=res)
    assert best == CommConfig("overlap", 4)
    assert len(calls) == len(autotune_candidates())
    assert res and min(res.values()) == 0.001

    # same key: cache hit, the timer must not run again
    res2 = {}
    best2 = autotune_comm(("k1",), fake_time, cache_path="", results=res2)
    assert best2 == best
    assert len(calls) == len(autotune_candidates())
    assert res2 == {}


def test_autotune_persists_to_json_cache(tmp_path):
    clear_autotune_cache()
    path = str(tmp_path / "comm_cache.json")

    def timer(cfg):
        return 0.002 if cfg.strategy == "fused" else 0.02

    best = autotune_comm(("k2",), timer, cache_path=path)
    assert best == CommConfig("fused", 1)

    # a fresh process (simulated by clearing the in-memory cache) reads the
    # persisted winner without re-timing
    clear_autotune_cache()
    best2 = autotune_comm(
        ("k2",), lambda cfg: pytest.fail("must hit the disk cache"),
        cache_path=path)
    assert best2 == best


@pytest.mark.filterwarnings("ignore::RuntimeWarning")
def test_autotune_skips_failing_candidates():
    clear_autotune_cache()

    def flaky(cfg):
        if cfg.strategy != "pipelined":
            raise RuntimeError("no lowering")
        return 0.5 / cfg.n_chunks

    best = autotune_comm(("k3",), flaky, cache_path="")
    assert best.strategy == "pipelined"
    assert best.n_chunks == max(
        c.n_chunks for c in autotune_candidates() if c.strategy == "pipelined")

    def always_fails(cfg):
        raise RuntimeError("nope")

    assert autotune_comm(("k4",), always_fails, cache_path="") == CommConfig()


# -- HLO interleave census --------------------------------------------------

_FAKE_MLIR = """
module @jit_solve {
  func.func private @fft(%a: tensor<4xf32>) {
    %f = "stablehlo.fft"(%a)
  }
  func.func public @main(%x: tensor<8xf32>) {
    %0 = call @fft(%x)
    %1 = "stablehlo.all_to_all"(%0)
    %2 = "stablehlo.all_to_all"(%1)
    %3 = call @fft(%2)
    %4 = "stablehlo.all_to_all"(%3)
    %5 = call @fft(%4)
    %6 = call @fft(%5)
  }
}
"""


def test_comm_interleave_stats_census():
    stats = comm_interleave_stats(_FAKE_MLIR)
    assert stats["all_to_all"] == 3
    # one adjacent collective pair (1->2), one gap holding a transform (2->4)
    assert stats["adjacent_pairs"] == 1
    assert stats["gaps_with_compute"] == 1
    # only transforms between collectives count, not the pre/post ones
    assert stats["fft"] == 1


# -- valid-extent stage API + doubling-aware autotune keys ------------------

def test_stage_valid_extent_crops_and_repads():
    """_prepare: crop the split axis to its live extent, re-pad to the
    equal-split multiple of the mesh axis (no collective needed to test)."""
    import jax.numpy as jnp

    strat = make_strategy(CommConfig("a2a"), axis_sizes={"ax": 4})
    x = jnp.ones((10, 3))
    y = strat._prepare(x, "ax", 0, 7)       # crop 10 -> 7, pad to 8
    assert y.shape == (8, 3)
    np.testing.assert_array_equal(np.asarray(y[:7]), 1.0)
    np.testing.assert_array_equal(np.asarray(y[7:]), 0.0)
    # valid_extent=None is the dense/historical path: ship as-is
    assert strat._prepare(x, "ax", 0, None) is x
    # unknown axis name: crop only (caller owns divisibility)
    strat2 = make_strategy(CommConfig("a2a"))
    assert strat2._prepare(x, "ax", 0, 7).shape == (7, 3)


def test_autotune_key_includes_doubling():
    """A pruned and a dense plan of the SAME shape/mesh must never share a
    persisted autotune winner ($REPRO_COMM_CACHE staleness guard)."""
    import jax
    from repro.core.bc import BCType
    from repro.distributed.pencil import DistributedPoissonSolver

    U = (BCType.UNB, BCType.UNB)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    kw = dict(mesh=mesh, lazy_green=True)
    dp = DistributedPoissonSolver((8,) * 3, 1.0, (U, U, U), **kw)
    dd = DistributedPoissonSolver((8,) * 3, 1.0, (U, U, U),
                                  doubling="upfront", **kw)
    assert dp.autotune_key() != dd.autotune_key()
    assert ("doubling", "deferred") in dp.autotune_key()
    assert ("doubling", "upfront") in dd.autotune_key()


def test_autotune_cache_not_replayed_across_doubling_modes(tmp_path):
    """End-to-end staleness guard: a JSON cache winner recorded for the
    dense plan must NOT short-circuit the pruned plan's sweep."""
    import jax
    import jax.numpy as jnp
    from repro.core.bc import BCType
    from repro.distributed.pencil import DistributedPoissonSolver

    clear_autotune_cache()
    path = str(tmp_path / "comm_cache.json")
    U = (BCType.UNB, BCType.UNB)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cands = (CommConfig("a2a", 1),)
    kw = dict(mesh=mesh, comm="auto", dtype=jnp.float64,
              autotune_candidates=cands, autotune_cache=path)
    dd = DistributedPoissonSolver((8,) * 3, 1.0, (U, U, U),
                                  doubling="upfront", **kw)
    assert dd.autotune_results, "dense construction must sweep live"
    dp = DistributedPoissonSolver((8,) * 3, 1.0, (U, U, U),
                                  doubling="deferred", **kw)
    assert dp.autotune_results, (
        "pruned plan replayed the dense plan's cached winner")
    # both entries coexist under distinct keys in the persisted JSON
    # (schema-2 envelope: {"schema": 2, "entries": {...}})
    import json
    with open(path) as fh:
        data = json.load(fh)
    assert data["schema"] == 2, data
    entries = data["entries"]
    assert len(entries) == 2, list(entries)
    assert sum("'doubling', 'upfront'" in k for k in entries) == 1
    assert sum("'doubling', 'deferred'" in k for k in entries) == 1
