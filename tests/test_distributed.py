"""Distributed pencil solver == reference solver, for all comm strategies.

Runs in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test session keeps seeing a single device.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core.bc import BCType, DataLayout
from repro.core.comm import CommConfig
from repro.core.green import GreenKind
from repro.core.solver import PoissonSolver
from repro.distributed.pencil import DistributedPoissonSolver

E, O, P, U = BCType.EVEN, BCType.ODD, BCType.PER, BCType.UNB
cfg = json.loads(sys.argv[1])
bcs = [tuple(getattr(BCType, b) for b in pair) for pair in cfg["bcs"]]
layout = DataLayout[cfg["layout"]]
n = cfg["n"]
mesh = jax.make_mesh((2, 4), ("data", "model"))

ref = PoissonSolver((n, n, n), 1.0, bcs, layout=layout,
                    green_kind=cfg["green"])
rng = np.random.default_rng(0)
f = rng.standard_normal(ref.input_shape)
want = np.asarray(ref.solve(jnp.asarray(f)))

for strategy in ("a2a", "pipelined", "fused"):
    ds = DistributedPoissonSolver(
        (n, n, n), 1.0, bcs, layout=layout, green_kind=cfg["green"],
        mesh=mesh, comm=CommConfig(strategy=strategy, n_chunks=2),
        dtype=jnp.float64)
    got = np.asarray(ds.solve(f))
    err = np.max(np.abs(got - want))
    assert err < 1e-10, (strategy, err)
    # batched (multi-pod style): 2 fields over an extra mesh axis
    if cfg.get("batch"):
        mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ds3 = DistributedPoissonSolver(
            (n, n, n), 1.0, bcs, layout=layout, green_kind=cfg["green"],
            mesh=mesh3, comm=CommConfig(strategy=strategy),
            batch_axis="pod", dtype=jnp.float64)
        fb = np.stack([f, 2.0 * f])
        gotb = np.asarray(ds3.solve(fb))
        assert np.max(np.abs(gotb[0] - want)) < 1e-10
        assert np.max(np.abs(gotb[1] - 2.0 * want)) < 1e-10
print("OK")
"""


def _run(cfg):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, json.dumps(cfg)],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


CASES = [
    # spectral mix (paper case A), node layout: N+1 points -> uneven split
    dict(bcs=[("EVEN", "EVEN"), ("ODD", "EVEN"), ("PER", "PER")],
         layout="NODE", n=16, green="chat2", batch=True),
    dict(bcs=[("EVEN", "EVEN"), ("ODD", "EVEN"), ("PER", "PER")],
         layout="CELL", n=16, green="chat2"),
    # fully unbounded (domain doubling through the switches)
    dict(bcs=[("UNB", "UNB"), ("UNB", "UNB"), ("UNB", "UNB")],
         layout="NODE", n=16, green="chat2"),
    # semi-unbounded + unbounded mix (paper case C)
    dict(bcs=[("UNB", "EVEN"), ("UNB", "UNB"), ("ODD", "UNB")],
         layout="CELL", n=16, green="hej2"),
]


@pytest.mark.parametrize("cfg", CASES, ids=lambda c: f"{c['layout']}-{c['bcs'][0][0]}{c['bcs'][2][0]}")
def test_distributed_matches_reference(cfg):
    _run(cfg)
