"""Distributed pencil solver == reference solver, for all comm strategies.

Runs in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test session keeps seeing a single device.  Covers the four
``CommStrategy`` classes plus ``comm="auto"`` (the plan-time autotuner), the
lowered-HLO interleaving signature of the ``overlap`` strategy, and the
pad-instead-of-silent-fallback behavior for prime-length chunk axes.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core.bc import BCType, DataLayout
from repro.core.comm import CommConfig
from repro.core.green import GreenKind
from repro.core.solver import PoissonSolver
from repro.distributed.pencil import DistributedPoissonSolver

E, O, P, U = BCType.EVEN, BCType.ODD, BCType.PER, BCType.UNB
cfg = json.loads(sys.argv[1])
bcs = [tuple(getattr(BCType, b) for b in pair) for pair in cfg["bcs"]]
layout = DataLayout[cfg["layout"]]
n = cfg["n"]
mesh = jax.make_mesh((2, 4), ("data", "model"))

ref = PoissonSolver((n, n, n), 1.0, bcs, layout=layout,
                    green_kind=cfg["green"])
rng = np.random.default_rng(0)
f = rng.standard_normal(ref.input_shape)
want = np.asarray(ref.solve(jnp.asarray(f)))

for strategy in ("a2a", "pipelined", "fused", "overlap"):
    ds = DistributedPoissonSolver(
        (n, n, n), 1.0, bcs, layout=layout, green_kind=cfg["green"],
        mesh=mesh, comm=CommConfig(strategy=strategy, n_chunks=2),
        dtype=jnp.float64)
    got = np.asarray(ds.solve(f))
    err = np.max(np.abs(got - want))
    assert err < 1e-10, (strategy, err)
    # in-block multi-RHS batch: solve((B, *grid)) == stacked single solves
    # (B=4 divides n_chunks=2 -> chunked strategies cut along the batch)
    if cfg.get("local_batch"):
        scales = (1.0, -0.5, 2.0, 0.25)
        fb = np.stack([a * f for a in scales])
        gotb = np.asarray(ds.solve(fb))
        for a, g1 in zip(scales, gotb):
            errb = np.max(np.abs(g1 - a * want))
            assert errb < 1e-9, (strategy, "local_batch", errb)
    # batched (multi-pod style): 2 fields over an extra mesh axis
    if cfg.get("batch"):
        mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ds3 = DistributedPoissonSolver(
            (n, n, n), 1.0, bcs, layout=layout, green_kind=cfg["green"],
            mesh=mesh3, comm=CommConfig(strategy=strategy),
            batch_axis="pod", dtype=jnp.float64)
        fb = np.stack([f, 2.0 * f])
        gotb = np.asarray(ds3.solve(fb))
        assert np.max(np.abs(gotb[0] - want)) < 1e-10
        assert np.max(np.abs(gotb[1] - 2.0 * want)) < 1e-10

if cfg.get("auto"):
    # plan-time autotuner: picks a strategy with no user input, result is
    # still exact, and the winner is cached per (shape, bcs, layout, mesh)
    ds = DistributedPoissonSolver(
        (n, n, n), 1.0, bcs, layout=layout, green_kind=cfg["green"],
        mesh=mesh, comm="auto", dtype=jnp.float64)
    assert isinstance(ds.comm, CommConfig), ds.comm
    # guided search (the default) times only the cost-model shortlist --
    # a strict subset of the candidate space (DESIGN.md #12)
    assert len(ds.autotune_results) >= 1, ds.autotune_results
    cen = ds.autotune_census
    assert cen["space"] >= 4, cen
    assert 1 <= len(cen["shortlist"]) < cen["space"], cen
    assert set(ds.autotune_results) == set(cen["shortlist"])
    got = np.asarray(ds.solve(f))
    assert np.max(np.abs(got - want)) < 1e-10
    ds2 = DistributedPoissonSolver(
        (n, n, n), 1.0, bcs, layout=layout, green_kind=cfg["green"],
        mesh=mesh, comm="auto", dtype=jnp.float64)
    assert ds2.comm == ds.comm
    assert ds2.autotune_results == {}, "second construction must hit cache"
print("OK")
"""


def _run_script(script, *args):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    # a developer's persisted autotune cache must not leak into the
    # comm="auto" assertions (they require a live sweep)
    env.pop("REPRO_COMM_CACHE", None)
    out = subprocess.run(
        [sys.executable, "-c", script, *args],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
    return out


def _run(cfg):
    _run_script(_SCRIPT, json.dumps(cfg))


CASES = [
    # spectral mix (paper case A), node layout: N+1 points -> uneven split
    dict(bcs=[("EVEN", "EVEN"), ("ODD", "EVEN"), ("PER", "PER")],
         layout="NODE", n=16, green="chat2", batch=True),
    dict(bcs=[("EVEN", "EVEN"), ("ODD", "EVEN"), ("PER", "PER")],
         layout="CELL", n=16, green="chat2", auto=True, local_batch=True),
    # fully unbounded (domain doubling through the switches)
    dict(bcs=[("UNB", "UNB"), ("UNB", "UNB"), ("UNB", "UNB")],
         layout="NODE", n=16, green="chat2", local_batch=True),
    # semi-unbounded + unbounded mix (paper case C)
    dict(bcs=[("UNB", "EVEN"), ("UNB", "UNB"), ("ODD", "UNB")],
         layout="CELL", n=16, green="hej2"),
    # mixed-BC NODE without batch: the N+1 uneven split through every
    # strategy including the chunk-padded overlap path
    dict(bcs=[("ODD", "ODD"), ("EVEN", "ODD"), ("PER", "PER")],
         layout="NODE", n=12, green="chat2"),
]


@pytest.mark.parametrize("cfg", CASES, ids=lambda c: f"{c['layout']}-{c['bcs'][0][0]}{c['bcs'][2][0]}-n{c['n']}")
def test_distributed_matches_reference(cfg):
    _run(cfg)


_HLO_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver
from repro.launch.hlo_stats import comm_interleave_stats

U = (BCType.UNB, BCType.UNB)
mesh = jax.make_mesh((2, 4), ("data", "model"))
NC = 4
stats = {}
for strat, nc in (("a2a", 1), ("pipelined", NC), ("overlap", NC)):
    ds = DistributedPoissonSolver((16,) * 3, 1.0, (U, U, U), mesh=mesh,
                                  comm=CommConfig(strat, nc),
                                  lazy_green=True)
    stats[strat] = comm_interleave_stats(ds.lower().as_text())
a2a, pipe, ov = stats["a2a"], stats["pipelined"], stats["overlap"]
# 4 topology switches per solve (2 forward + 2 backward)
assert a2a["all_to_all"] == 4, a2a
assert pipe["all_to_all"] == 4 * NC, pipe
assert ov["all_to_all"] >= 4 * NC, ov
# the overlap signature: 1-D transform ops are scheduled BETWEEN the chunked
# collectives of a switch (chunk k's transform after chunk k+1's all-to-all)
assert ov["gaps_with_compute"] >= 4 * (NC - 2), ov
# pipelined chunks the collective only -- compute sits at switch
# boundaries, never inside a chunk train
assert ov["gaps_with_compute"] > pipe["gaps_with_compute"], (ov, pipe)
print("OK")
"""


def test_overlap_hlo_interleaves_transforms_with_collectives():
    _run_script(_HLO_SCRIPT)


_PRIME_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
from jax.sharding import PartitionSpec as P
from repro.core.comm import CommConfig, topology_switch

mesh = jax.make_mesh((2,), ("ax",))
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map

# uninvolved (chunk) axis has PRIME length 7: n_chunks=2 cannot divide it.
# The seed silently fell back to one monolithic collective; now the axis is
# zero-padded to the next multiple (and cropped back) with a warning.
x = np.random.default_rng(0).standard_normal((4, 6, 7))

def run(cfg):
    fn = shard_map(lambda xl: topology_switch(xl, "ax", 0, 1, cfg),
                   mesh=mesh, in_specs=P(None, "ax", None),
                   out_specs=P("ax", None, None))
    return np.asarray(jax.jit(fn)(x))

want = run(CommConfig("a2a", 1))
with warnings.catch_warnings(record=True) as rec:
    warnings.simplefilter("always")
    got = run(CommConfig("pipelined", 2))
msgs = [str(w.message) for w in rec if "zero-padding" in str(w.message)]
assert msgs, "non-dividing chunk axis must warn"
np.testing.assert_allclose(got, want, rtol=0, atol=0)

# the chunked path must emit n_chunks collectives, not a silent single one
lowered = jax.jit(shard_map(
    lambda xl: topology_switch(xl, "ax", 0, 1, CommConfig("pipelined", 2)),
    mesh=mesh, in_specs=P(None, "ax", None),
    out_specs=P("ax", None, None))).lower(x).as_text()
assert lowered.count("all_to_all") + lowered.count("all-to-all") >= 2, \
    "pipelined must keep its chunked collectives on a non-dividing axis"

# overlap shares the padding path
with warnings.catch_warnings():
    warnings.simplefilter("ignore")
    got_ov = run(CommConfig("overlap", 2))
np.testing.assert_allclose(got_ov, want, rtol=0, atol=0)
print("OK")
"""


def test_pipelined_prime_chunk_axis_pads_and_warns():
    _run_script(_PRIME_SCRIPT)
