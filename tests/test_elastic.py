"""Elastic re-scale: checkpoint written on a (2,4) mesh restores onto a
(4,2) mesh and training continues bit-compatibly (DESIGN.md section 6)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt import checkpoint as ck
from repro.configs import get_smoke
from repro.data.pipeline import synthetic_batch
from repro.models.transformer import param_specs
from repro.training.train_step import make_train_state, train_step_fn, \
    TrainState
from repro.training import optimizer as opt

cfg = get_smoke("minitron-8b")
d = "/tmp/elastic_ck"

def shard_state(state, mesh):
    pspec = param_specs(cfg, dict(mesh.shape))
    def put(tree, spec_tree):
        return jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            tree, spec_tree, is_leaf=lambda x: isinstance(x, P))
    # smoke dims don't divide the mesh -> replicate (spec compatibility is
    # what we exercise; real configs shard)
    return jax.tree.map(lambda a: jax.device_put(
        a, NamedSharding(mesh, P())), state)

# train 2 steps on mesh A, checkpoint
mesh_a = jax.make_mesh((2, 4), ("data", "model"))
state = make_train_state(jax.random.PRNGKey(0), cfg)
state = shard_state(state, mesh_a)
step = jax.jit(train_step_fn(cfg))
for i in range(2):
    state, _ = step(state, synthetic_batch(cfg, i, 2, 16))
ck.save(d, 2, state)

# restore onto mesh B (different layout), continue 2 steps
mesh_b = jax.make_mesh((4, 2), ("data", "model"))
like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
shards = jax.tree.map(lambda a: NamedSharding(mesh_b, P()), state)
state_b = ck.restore(d, 2, like, shardings=shards)
for i in range(2, 4):
    state_b, mb = step(state_b, synthetic_batch(cfg, i, 2, 16))

# reference: 4 straight steps on one device
ref = make_train_state(jax.random.PRNGKey(0), cfg)
for i in range(4):
    ref, mr = step(ref, synthetic_batch(cfg, i, 2, 16))

for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(state_b.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-6)
print("OK elastic")
"""


@pytest.mark.slow
def test_elastic_mesh_rescale(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK elastic" in out.stdout


_SOLVER_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from jax.sharding import Mesh
from repro.core.bc import BCType
from repro.core.solver import get_solver, solver_cache_info
from repro.distributed.pencil import DistributedPoissonSolver

E, O, P = BCType.EVEN, BCType.ODD, BCType.PER
bcs = ((E, E), (O, E), (P, P))
shape = (16, 16, 16)
rng = np.random.default_rng(0)
f = rng.standard_normal(shape).astype(np.float32)

mesh_a = jax.make_mesh((2, 4), ("data", "model"))
s = get_solver(shape, 1.0, bcs, mesh=mesh_a, engine="xla")
want = np.asarray(s.solve(f))

# rebuild onto (4,2): different pencil splits, same devices -- the raw
# Green's function is handed over (never reassembled) and the result is
# bit-identical on the xla engine
mesh_b = jax.make_mesh((4, 2), ("data", "model"))
s_b = s.rebuild(mesh_b)
assert np.array_equal(np.asarray(s_b.solve(f)), want)
assert s_b._green_raw is s._green_raw, "Green reassembled on rebuild"

# degenerate surviving mesh (8,1): one pencil axis collapses entirely
mesh_c = Mesh(np.array(jax.devices()[:8]).reshape(8, 1),
              ("data", "model"))
s_c = s_b.rebuild(mesh_c)
assert np.array_equal(np.asarray(s_c.solve(f)), want)

# rebuild evicted the old-mesh get_solver entry: re-acquiring on mesh_a
# constructs FRESH (miss), never serving a solver bound to "dead" devices
before = solver_cache_info()["misses"]
s2 = get_solver(shape, 1.0, bcs, mesh=mesh_a, engine="xla")
assert s2 is not s
assert solver_cache_info()["misses"] == before + 1
print("OK solver elastic")
"""


def test_solver_elastic_rebuild():
    # ISSUE 6 satellite: solve on (2,4), rebuild to (4,2) and (8,1),
    # bit-exact vs the fault-free baseline on the xla engine
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SOLVER_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK solver elastic" in out.stdout
