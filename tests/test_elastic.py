"""Elastic re-scale: checkpoint written on a (2,4) mesh restores onto a
(4,2) mesh and training continues bit-compatibly (DESIGN.md section 6)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt import checkpoint as ck
from repro.configs import get_smoke
from repro.data.pipeline import synthetic_batch
from repro.models.transformer import param_specs
from repro.training.train_step import make_train_state, train_step_fn, \
    TrainState
from repro.training import optimizer as opt

cfg = get_smoke("minitron-8b")
d = "/tmp/elastic_ck"

def shard_state(state, mesh):
    pspec = param_specs(cfg, dict(mesh.shape))
    def put(tree, spec_tree):
        return jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            tree, spec_tree, is_leaf=lambda x: isinstance(x, P))
    # smoke dims don't divide the mesh -> replicate (spec compatibility is
    # what we exercise; real configs shard)
    return jax.tree.map(lambda a: jax.device_put(
        a, NamedSharding(mesh, P())), state)

# train 2 steps on mesh A, checkpoint
mesh_a = jax.make_mesh((2, 4), ("data", "model"))
state = make_train_state(jax.random.PRNGKey(0), cfg)
state = shard_state(state, mesh_a)
step = jax.jit(train_step_fn(cfg))
for i in range(2):
    state, _ = step(state, synthetic_batch(cfg, i, 2, 16))
ck.save(d, 2, state)

# restore onto mesh B (different layout), continue 2 steps
mesh_b = jax.make_mesh((4, 2), ("data", "model"))
like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
shards = jax.tree.map(lambda a: NamedSharding(mesh_b, P()), state)
state_b = ck.restore(d, 2, like, shardings=shards)
for i in range(2, 4):
    state_b, mb = step(state_b, synthetic_batch(cfg, i, 2, 16))

# reference: 4 straight steps on one device
ref = make_train_state(jax.random.PRNGKey(0), cfg)
for i in range(4):
    ref, mr = step(ref, synthetic_batch(cfg, i, 2, 16))

for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(state_b.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-6)
print("OK elastic")
"""


@pytest.mark.slow
def test_elastic_mesh_rescale(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK elastic" in out.stdout
