"""TransformEngine: xla/pallas equivalence + plan-time normalization folding.

The acceptance bar for the engine layer:
  * ``engine="pallas"`` (interpret mode) matches ``engine="xla"`` within
    1e-5 on full mixed-BC solves (both solvers);
  * the solve emits ZERO standalone normalization multiplies -- the only
    float-array multiply in the jaxpr is the fused Green multiply.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.bc import BCType, DataLayout
from repro.core.engine import (TransformEngine, as_engine, build_schedule)
from repro.core.green import GreenKind
from repro.core.solver import PoissonSolver, make_plan

import sys
import os
sys.path.insert(0, os.path.dirname(__file__))
from test_poisson import CASES  # noqa: E402

E, O, P, U = BCType.EVEN, BCType.ODD, BCType.PER, BCType.UNB


def test_engine_resolution():
    assert as_engine(None).name == "xla"
    assert as_engine("pallas").use_pallas
    assert as_engine(TransformEngine("xla")) == TransformEngine("xla")
    with pytest.raises(ValueError):
        TransformEngine("cuda")


def test_schedule_folds_all_normfacts():
    plan = make_plan((16, 16, 16), 1.0, ((E, E), (O, E), (P, P)),
                     DataLayout.CELL)
    sched = build_schedule(plan, "xla")
    want = 1.0
    for p in plan.dirs:
        want *= p.normfact
    assert sched.norm == pytest.approx(want, rel=1e-15)
    # r2r dirs carry twiddle tables, the DFT dir carries none
    assert sched.fwd_tables[2] is None
    assert sched.fwd_tables[0] is not None


@pytest.mark.parametrize("case,layout", [
    ("A", DataLayout.CELL), ("A", DataLayout.NODE)])
def test_engines_match_on_mixed_bc_solve(case, layout):
    """pallas (interpret) == xla within 1e-5 on the paper's case A BCs."""
    fn, bcs = CASES[case]
    n = 32
    rhs, _ = fn(n, layout)
    kw = dict(layout=layout, green_kind=GreenKind.CHAT2)
    sx = PoissonSolver((n, n, n), 1.0, bcs, engine="xla", **kw)
    sp = PoissonSolver((n, n, n), 1.0, bcs, engine="pallas", **kw)
    ux = np.asarray(sx.solve(rhs.astype(np.float64)))
    up = np.asarray(sp.solve(rhs.astype(np.float64)))
    np.testing.assert_allclose(up, ux, rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_engines_match_on_unbounded_solve():
    """Semi/unbounded dirs (Hockney-doubled power-of-two FFTs) also match."""
    fn, bcs = CASES["C"]
    n = 16
    rhs, _ = fn(n, DataLayout.CELL)
    kw = dict(layout=DataLayout.CELL, green_kind=GreenKind.CHAT2)
    sx = PoissonSolver((n, n, n), 1.0, bcs, engine="xla", **kw)
    sp = PoissonSolver((n, n, n), 1.0, bcs, engine="pallas", **kw)
    ux = np.asarray(sx.solve(rhs.astype(np.float64)))
    up = np.asarray(sp.solve(rhs.astype(np.float64)))
    np.testing.assert_allclose(up, ux, rtol=1e-5, atol=1e-5)


def test_pallas_engine_actually_uses_kernels():
    """The pallas engine must put pallas_call ops in the traced solve."""
    n = 16
    s = PoissonSolver((n, n, n), 1.0, ((E, E), (O, E), (P, P)),
                      layout=DataLayout.CELL, engine="pallas")
    f = jnp.zeros(s.input_shape)
    trace = str(jax.make_jaxpr(s._solve_impl)(f))
    assert "pallas_call" in trace
    sx = PoissonSolver((n, n, n), 1.0, ((E, E), (O, E), (P, P)),
                       layout=DataLayout.CELL, engine="xla")
    assert "pallas_call" not in str(jax.make_jaxpr(sx._solve_impl)(f))


def test_zero_standalone_normalization_multiplies():
    """All-even node solve (DCT-I, twiddle-free): the ONLY float-array mul
    in the jaxpr is the fused Green multiply -- every per-direction
    normfact pass of the seed implementation is gone."""
    n = 16
    s = PoissonSolver((n, n, n), 1.0, ((E, E), (E, E), (E, E)),
                      layout=DataLayout.NODE, engine="xla")
    f = jnp.zeros(s.input_shape)
    jaxpr = jax.make_jaxpr(s._solve_impl)(f)
    float_muls = [
        eq for eq in jaxpr.jaxpr.eqns
        if eq.primitive.name == "mul"
        and any(jnp.issubdtype(v.aval.dtype, jnp.inexact)
                for v in eq.invars if hasattr(v, "aval"))
    ]
    assert len(float_muls) == 1, (
        f"expected exactly the Green multiply, got {len(float_muls)} "
        "float-array multiplies")


def test_green_folds_normalization():
    """build_green output includes prod(normfact): solving with an
    unnormalized manual pipeline reproduces the solver result."""
    from repro.core.solver import build_green
    from repro.core import transforms as tr
    n = 8
    plan = make_plan((n, n, n), 1.0, ((E, E), (E, E), (E, E)),
                     DataLayout.CELL)
    g = build_green(plan)
    norm = np.prod([p.normfact for p in plan.dirs])
    plain = g / norm
    # spectral symbol of the pure-Neumann problem is norm-free in `plain`
    w2 = sum(np.meshgrid(*[np.square(p.modes) for p in plan.dirs],
                         indexing="ij"))
    mask = w2 > 1e-12
    np.testing.assert_allclose(plain[mask], -1.0 / w2[mask], rtol=1e-10)


def test_distributed_engines_match():
    """DistributedPoissonSolver(engine="pallas") == engine="xla"."""
    from repro.distributed.pencil import DistributedPoissonSolver
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fn, bcs = CASES["A"]
    n = 16
    layout = DataLayout.CELL
    rhs, _ = fn(n, layout)
    kw = dict(layout=layout, green_kind=GreenKind.CHAT2, mesh=mesh,
              dtype=jnp.float64)
    sx = DistributedPoissonSolver((n, n, n), 1.0, bcs, engine="xla", **kw)
    sp = DistributedPoissonSolver((n, n, n), 1.0, bcs, engine="pallas", **kw)
    ux = np.asarray(sx.solve(rhs))
    up = np.asarray(sp.solve(rhs))
    np.testing.assert_allclose(up, ux, rtol=1e-5, atol=1e-5)


def test_distributed_matches_reference_with_pallas_engine():
    """Pallas-engine distributed solve still matches the single-process
    reference solver (mixed-BC validation of tests/test_poisson.py)."""
    from repro.distributed.pencil import DistributedPoissonSolver
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fn, bcs = CASES["A"]
    n = 16
    layout = DataLayout.CELL
    rhs, _ = fn(n, layout)
    ref = PoissonSolver((n, n, n), 1.0, bcs, layout=layout,
                        green_kind=GreenKind.CHAT2, engine="xla")
    ds = DistributedPoissonSolver(
        (n, n, n), 1.0, bcs, layout=layout, green_kind=GreenKind.CHAT2,
        mesh=mesh, dtype=jnp.float64, engine="pallas")
    want = np.asarray(ref.solve(rhs.astype(np.float64)))
    got = np.asarray(ds.solve(rhs))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
