"""Chaos suite: deterministic fault injection through the resilience layer.

Covers every fault class in ISSUE 6's acceptance criteria: stage NaN/Inf,
Pallas lowering failure, transient errors, hard faults (terminal
``SolveError``), corrupt autotune cache, torn/truncated checkpoints, and
-- in the 8-device subprocess tests -- comm faults walking the distributed
ladder plus device loss resuming the ``--steps`` loop from a checkpoint on
a shrunken mesh.  Recovered solves are compared BIT-EXACTLY against the
fault-free xla baseline.
"""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.bc import BCType
from repro.core.comm import CommConfig, autotune_comm, clear_autotune_cache
from repro.core.solver import PoissonSolver
from repro.ckpt import checkpoint as ck
from repro.runtime import faults, health, resilience
from repro.runtime.resilience import SolveError

E, O, P, U = BCType.EVEN, BCType.ODD, BCType.PER, BCType.UNB
BCS = ((E, E), (O, E), (P, P))


# -- fault-plan semantics ----------------------------------------------------

def test_fault_spec_after_count():
    plan = faults.FaultPlan([
        dict(kind="error", stage="stage.a", after=1, count=2)])
    with plan:
        faults.fail_point("stage.a")                 # hit 1: skipped (after)
        for _ in range(2):                           # hits 2-3: fire
            with pytest.raises(faults.InjectedFault):
                faults.fail_point("stage.a")
        faults.fail_point("stage.a")                 # count exhausted
        faults.fail_point("stage.b")                 # wrong stage
    faults.fail_point("stage.a")                     # plan deactivated
    assert [e["hit"] for e in plan.log] == [2, 3]


def test_fault_plan_from_env(monkeypatch, tmp_path):
    spec = [dict(kind="error", stage="x")]
    monkeypatch.setenv("REPRO_FAULTS", json.dumps(spec))
    with faults.plan_from_env():
        with pytest.raises(faults.InjectedFault):
            faults.fail_point("x")
    pf = tmp_path / "plan.json"
    pf.write_text(json.dumps(spec))
    monkeypatch.setenv("REPRO_FAULTS", str(pf))
    with faults.plan_from_env():
        with pytest.raises(faults.InjectedFault):
            faults.fail_point("x")
    monkeypatch.delenv("REPRO_FAULTS")
    assert faults.plan_from_env() is None


def test_taint_and_step_matching():
    import jax.numpy as jnp
    with faults.FaultPlan([dict(kind="nan", stage="green")]):
        x = faults.taint("green", jnp.ones((2, 3)))
        assert not bool(jnp.isfinite(x).all())
        assert bool(jnp.isfinite(faults.taint("green", jnp.ones(3))).all())
    with faults.FaultPlan([dict(kind="device_loss", step=3)]) as plan:
        assert not faults.should_fire("device_loss", step=2)
        assert faults.should_fire("device_loss", step=3)
        assert plan.log[0]["step"] == 3


# -- ladder unit behaviour ---------------------------------------------------

def test_ladder_rung_order():
    cfg = {"engine": "pallas", "comm": "overlap",
           "relayout": "scheduled", "doubling": "deferred"}
    trail = []
    while True:
        step = resilience.next_rung(cfg)
        if step is None:
            break
        cfg, action = step
        trail.append(action)
    assert trail == ["engine:pallas->xla", "comm:overlap->pipelined",
                     "comm:pipelined->a2a", "relayout:scheduled->baseline",
                     "doubling:deferred->upfront"]
    # single-process configs have no comm knob: it is skipped, not an error
    cfg = {"engine": "xla", "relayout": "baseline", "doubling": "upfront"}
    assert resilience.next_rung(cfg) is None


def test_transient_retry_then_exhaust():
    calls = {"n": 0}
    cfg = {"engine": "xla", "relayout": "baseline", "doubling": "upfront"}

    def attempt():
        calls["n"] += 1
        raise faults.InjectedFault("s", "error", transient=True)

    stats = {"retries": 0, "degradations": []}
    with pytest.raises(SolveError) as ei:
        resilience.run_with_ladder(
            attempt, config=cfg, reconfigure=lambda c: None, stats=stats,
            policy=resilience.RetryPolicy(retries=3, base_delay=0),
            sleep=lambda s: None)
    assert calls["n"] == 4 and stats["retries"] == 3
    assert ei.value.stage == "s" and not ei.value.degradations


def _retry_delays(policy, retries=6):
    """Drive run_with_ladder with always-transient failures and capture
    the backoff delays it would have slept."""
    delays = []
    cfg = {"engine": "xla", "relayout": "baseline", "doubling": "upfront"}

    def attempt():
        raise faults.InjectedFault("s", "error", transient=True)

    with pytest.raises(SolveError):
        resilience.run_with_ladder(
            attempt, config=cfg, reconfigure=lambda c: None,
            stats={"degradations": []}, policy=policy,
            sleep=delays.append)
    return delays


def test_decorrelated_jitter_spreads_retry_storms():
    """Co-batched tenants tripping on the same transient must NOT retry
    in lockstep: seeded decorrelated jitter is deterministic per seed,
    spread across seeds, and bounded by [base, max]; ``jitter="none"``
    restores the fixed doubling schedule."""
    mk = lambda **kw: resilience.RetryPolicy(
        retries=6, base_delay=0.05, max_delay=1.0, **kw)
    fixed = _retry_delays(mk(jitter="none"))
    assert fixed == [0.05, 0.1, 0.2, 0.4, 0.8, 1.0]
    a = _retry_delays(mk(seed=1))
    assert a == _retry_delays(mk(seed=1)), "seeded jitter not reproducible"
    assert all(0.05 <= d <= 1.0 for d in a)
    # default schedule actually jitters: not the doubling ramp, and two
    # tenants with different seeds retry at different times
    assert a != fixed
    others = [_retry_delays(mk(seed=s)) for s in range(2, 8)]
    assert all(o != a for o in others)
    # spread, not clustering: pairwise distinct delays at every step >1
    step1 = {round(d[1], 9) for d in [a] + others}
    assert len(step1) >= 5, f"retry storm not decorrelated: {step1}"


def test_retry_seed_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_RETRY_SEED", "1234")
    a = _retry_delays(resilience.RetryPolicy(retries=5, base_delay=0.05))
    b = _retry_delays(resilience.RetryPolicy(retries=5, base_delay=0.05))
    assert a == b, "$REPRO_RETRY_SEED did not pin the jitter RNG"
    monkeypatch.setenv("REPRO_RETRY_SEED", "99")
    assert _retry_delays(
        resilience.RetryPolicy(retries=5, base_delay=0.05)) != a
    # explicit seed wins over the environment
    monkeypatch.setenv("REPRO_RETRY_SEED", "1234")
    c = _retry_delays(resilience.RetryPolicy(retries=5, base_delay=0.05,
                                             seed=7))
    monkeypatch.delenv("REPRO_RETRY_SEED")
    assert c == _retry_delays(resilience.RetryPolicy(retries=5,
                                                     base_delay=0.05,
                                                     seed=7))


# -- solver-level recovery (single process, bit-exact) -----------------------

def _rhs(shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32)


def test_nan_injection_recovers_bit_exact():
    s0 = PoissonSolver((12, 12, 12), 1.0, BCS, engine="xla")
    f = _rhs(s0.input_shape)
    want = np.asarray(s0.solve(f))
    s = PoissonSolver((12, 12, 12), 1.0, BCS, engine="xla", verify="nan")
    with faults.FaultPlan([dict(kind="nan", stage="green")]) as plan:
        got = np.asarray(s.solve(f))
    assert plan.log, "fault never fired"
    assert s.stats["verify_failures"] == 1
    assert len(s.stats["degradations"]) == 1
    assert s.stats["degradations"][0]["stage"].startswith("verify.nan@")
    assert np.array_equal(got, want)


def test_pallas_lowering_failure_degrades_to_xla():
    want = None
    sx = PoissonSolver((12, 12, 12), 1.0, BCS, engine="xla")
    f = _rhs(sx.input_shape)
    want = np.asarray(sx.solve(f))
    sp = PoissonSolver((12, 12, 12), 1.0, BCS, engine="pallas")
    with faults.FaultPlan([dict(kind="pallas_lowering", stage="pallas.*",
                                count=-1)]):
        got = np.asarray(sp.solve(f))
    acts = [d["action"] for d in sp.stats["degradations"]]
    assert acts == ["engine:pallas->xla"]
    assert sp._cfg["engine"] == "xla"
    assert np.array_equal(got, want)


def test_residual_verify_passes_healthy_and_catches_corruption():
    n = 16
    h = 1.0 / n
    pts = (np.arange(n) + 0.5) * h
    x, y, z = np.meshgrid(pts, pts, pts, indexing="ij")
    sol = np.sin(2 * np.pi * x) * np.sin(4 * np.pi * y) * \
        np.cos(2 * np.pi * z)
    rhs = (-(4 + 16 + 4) * np.pi ** 2 * sol).astype(np.float64)
    s = PoissonSolver((n, n, n), 1.0, ((P, P),) * 3, verify="residual")
    s.solve(rhs)
    assert s.stats["last_residual"] < 0.05
    # a corrupted (inf) green multiply must trip the residual/nan guard and
    # recover down the ladder to the same bits as a fault-free solve
    want = np.asarray(PoissonSolver((n, n, n), 1.0, ((P, P),) * 3).solve(rhs))
    with faults.FaultPlan([dict(kind="inf", stage="green")]):
        got = np.asarray(s.solve(rhs))
    assert s.stats["verify_failures"] == 1
    assert np.array_equal(got, want)


def test_hard_fault_raises_structured_solve_error():
    s = PoissonSolver((8, 8, 8), 1.0, BCS)
    f = _rhs(s.input_shape)
    with faults.FaultPlan([dict(kind="error", stage="solve.dispatch",
                                count=-1)]):
        with pytest.raises(SolveError) as ei:
            s.solve(f)
    e = ei.value
    assert e.stage == "solve.dispatch"
    assert [d["action"] for d in e.degradations] == \
        ["relayout:scheduled->baseline", "doubling:deferred->upfront"]
    assert e.config["doubling"] == "upfront"


def test_fault_token_isolates_get_solver_cache():
    from repro.core.solver import get_solver
    s_clean = get_solver((8, 8, 8), 1.0, BCS)
    with faults.FaultPlan([dict(kind="nan", stage="green")]):
        s_armed = get_solver((8, 8, 8), 1.0, BCS)
    assert s_armed is not s_clean
    assert get_solver((8, 8, 8), 1.0, BCS) is s_clean


# -- autotune cache corruption + budget --------------------------------------

def test_corrupt_autotune_cache_falls_through_to_sweep(tmp_path):
    clear_autotune_cache()
    path = str(tmp_path / "comm.json")
    times = {"a2a:1": 3.0, "pipelined:2": 1.0, "pipelined:4": 2.0}

    def timer(cfg):
        return times[f"{cfg.strategy}:{cfg.n_chunks}"]

    cands = [CommConfig("a2a", 1), CommConfig("pipelined", 2),
             CommConfig("pipelined", 4)]
    best = autotune_comm(("kc",), timer, candidates=cands, cache_path=path)
    assert best.strategy == "pipelined" and best.n_chunks == 2
    clear_autotune_cache()
    # rot every entry on load: the loader must ignore the garbage and a
    # live sweep must still find the winner
    with faults.FaultPlan([dict(kind="corrupt_cache", count=-1)]):
        census = {}
        best2 = autotune_comm(("kc",), timer, candidates=cands,
                              cache_path=path, census=census)
    assert best2 == best
    assert len(census["timed"]) == 3


def test_autotune_budget_skips_stallers():
    clear_autotune_cache()

    def timer(cfg):
        if cfg.strategy == "overlap":
            time.sleep(5.0)          # the pathological candidate
        return {"a2a": 2.0, "pipelined": 1.0}[cfg.strategy]

    cands = [CommConfig("a2a", 1), CommConfig("overlap", 2),
             CommConfig("pipelined", 2)]
    census = {}
    t0 = time.perf_counter()
    best = autotune_comm(("kb",), timer, candidates=cands, cache_path="",
                         budget_s=0.2, census=census)
    assert time.perf_counter() - t0 < 4.0, "budget did not bound the sweep"
    assert best.strategy == "pipelined"
    assert census["skipped_budget"] == ["overlap:2"]
    assert set(census["timed"]) == {"a2a:1", "pipelined:2"}


# -- checkpoint integrity ----------------------------------------------------

def _tree(step):
    return {"w": np.full((4, 3), float(step)), "b": np.arange(5.0)}


def test_restore_validates_manifest(tmp_path):
    d = str(tmp_path)
    ck.save(d, 0, _tree(0))
    like = _tree(0)
    out = ck.restore(d, 0, like)
    assert np.array_equal(out["w"], _tree(0)["w"])
    with pytest.raises(ck.CheckpointError, match="leaves"):
        ck.restore(d, 0, {"w": like["w"]})
    with pytest.raises(ck.CheckpointError, match="shape"):
        ck.restore(d, 0, {"w": np.zeros((2, 2)), "b": like["b"]})


def test_truncated_array_skips_step(tmp_path):
    d = str(tmp_path)
    for s in (0, 1, 2):
        ck.save(d, s, _tree(s))
    assert ck.all_steps(d) == [0, 1, 2]
    # torn write past the rename / disk rot: truncate one leaf of step 2
    bad = os.path.join(d, "step_2", "arr_0.npy")
    with open(bad, "r+b") as fh:
        fh.truncate(os.path.getsize(bad) // 2)
    assert ck.all_steps(d) == [0, 1]
    assert ck.latest_step(d) == 1           # restart falls back
    with pytest.raises(ck.CheckpointError, match="damaged"):
        ck.restore(d, 2, _tree(2))
    os.remove(os.path.join(d, "step_1", "arr_1.npy"))
    assert ck.latest_step(d) == 0           # missing leaf also skipped
    out = ck.restore(d, 0, _tree(0))
    assert np.array_equal(out["w"], _tree(0)["w"])


def test_torn_write_mid_leaf_preserves_previous_step(tmp_path):
    d = str(tmp_path)
    ck.save(d, 0, _tree(0))
    with faults.FaultPlan([dict(kind="torn_write", stage="ckpt.leaf.1")]):
        with pytest.raises(faults.InjectedFault):
            ck.save(d, 1, _tree(1))
    # the torn step never committed; the previous one is intact
    assert ck.all_steps(d) == [0]
    out = ck.restore(d, 0, _tree(0))
    assert np.array_equal(out["w"], _tree(0)["w"])
    # a retry of the same save succeeds over the leftover tmp dir
    ck.save(d, 1, _tree(1))
    assert ck.latest_step(d) == 1


# -- distributed chaos (8-device subprocess) ---------------------------------

_DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver
from repro.runtime import faults, resilience

P = BCType.PER
bcs = ((P, P),) * 3
mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = (16, 16, 16)
rng = np.random.default_rng(0)
f = rng.standard_normal(shape).astype(np.float32)

kw = dict(mesh=mesh, engine="xla")
want = np.asarray(DistributedPoissonSolver(shape, 1.0, bcs, **kw).solve(f))

# hard comm fault in the pipelined strategy: ladder lands on a2a, bit-exact
s = DistributedPoissonSolver(shape, 1.0, bcs,
                             comm=CommConfig("pipelined", 2), **kw)
with faults.FaultPlan([dict(kind="error", stage="comm.pipelined",
                            count=-1)]) as plan:
    got = np.asarray(s.solve(f))
assert plan.log, "comm fault never fired"
assert [d["action"] for d in s.stats["degradations"]] == \
    ["comm:pipelined->a2a"], s.stats["degradations"]
assert np.array_equal(got, want)

# NaN injected into the green stage: verify catches it with stage
# provenance, one rung down recovers bit-exactly
s = DistributedPoissonSolver(shape, 1.0, bcs, verify="nan", **kw)
with faults.FaultPlan([dict(kind="nan", stage="green")]):
    got = np.asarray(s.solve(f))
assert s.stats["verify_failures"] == 1
assert s.stats["degradations"][0]["stage"].startswith("verify.nan@")
assert np.array_equal(got, want)

# transient dispatch errors: backoff retries, no degradation
s = DistributedPoissonSolver(shape, 1.0, bcs, **kw)
with faults.FaultPlan([dict(kind="error", stage="dist.dispatch", count=2,
                            transient=True)]):
    got = np.asarray(s.solve(f))
assert s.stats["retries"] == 2 and not s.stats["degradations"]
assert np.array_equal(got, want)

# ladder exhaustion -> structured SolveError with provenance + trail
s = DistributedPoissonSolver(shape, 1.0, bcs, **kw)
try:
    with faults.FaultPlan([dict(kind="error", stage="dist.dispatch",
                                count=-1)]):
        s.solve(f)
    raise SystemExit("expected SolveError")
except resilience.SolveError as e:
    assert e.stage == "dist.dispatch"
    assert len(e.degradations) == 2, e.degradations
print("OK chaos")
"""


def _run_sub(script, *argv, env_extra=None):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_COMM_CACHE", None)
    env.pop("REPRO_FAULTS", None)
    env.update(env_extra or {})
    out = subprocess.run([sys.executable, "-c", script, *argv],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out


def test_distributed_chaos_ladder():
    out = _run_sub(_DIST_SCRIPT)
    assert "OK chaos" in out.stdout


_LOSS_SCRIPT = r"""
import sys
from repro.launch import solve
err = solve.main(["--n", "16", "--p1", "2", "--p2", "4", "--bcs", "per",
                  "--steps", "6", "--ckpt", sys.argv[1],
                  "--ckpt-every", "2", "--verify", "nan"])
assert err < 1e-5, err
print("OK loss")
"""


@pytest.mark.slow
def test_steps_loop_survives_device_loss(tmp_path):
    # the --steps CFD loop: device loss injected at step 3 shrinks the mesh
    # (2,4)->(1,4), the solver rebuilds elastically and the loop resumes
    # from the last checkpoint; the accumulated field still matches the
    # analytical solution
    out = _run_sub(
        _LOSS_SCRIPT, str(tmp_path / "ck"),
        env_extra={
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "REPRO_FAULTS":
                '[{"kind": "device_loss", "stage": "driver", "step": 3}]'})
    assert "OK loss" in out.stdout
    assert "device loss at step 3" in out.stdout
    assert "(1x4) surviving mesh" in out.stdout
