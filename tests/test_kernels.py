"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ref, ops
from repro.kernels.fft_stockham import fft_stockham
from repro.kernels.spectral_scale import spectral_scale
from repro.kernels.twiddle_pack import twiddle_pack


@pytest.mark.parametrize("shape", [(8, 128), (32, 256), (129, 384),
                                   (7, 130)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_spectral_scale(shape, dtype):
    rng = np.random.default_rng(0)
    re, im, g = (rng.standard_normal(shape).astype(dtype) for _ in range(3))
    got_r, got_i = spectral_scale(jnp.asarray(re), jnp.asarray(im),
                                  jnp.asarray(g), 0.37)
    want_r, want_i = ref.spectral_scale_ref(re, im, g, 0.37)
    np.testing.assert_allclose(np.asarray(got_r), want_r, rtol=2e-6,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_i), want_i, rtol=2e-6,
                               atol=1e-6)


@pytest.mark.parametrize("shape", [(8, 128), (64, 257), (5, 96)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_twiddle_pack(shape, dtype):
    rng = np.random.default_rng(1)
    re, im = (rng.standard_normal(shape).astype(dtype) for _ in range(2))
    cos = np.cos(np.linspace(0, 1, shape[1])).astype(dtype)
    sin = np.sin(np.linspace(0, 1, shape[1])).astype(dtype)
    got = twiddle_pack(*map(jnp.asarray, (re, im, cos, sin)))
    want = ref.twiddle_dct2_ref(re, im, cos, sin)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-6, atol=1e-6)


@pytest.mark.parametrize("n", [8, 64, 256, 1024])
@pytest.mark.parametrize("batch", [1, 8, 13])
def test_fft_stockham_forward(n, batch):
    rng = np.random.default_rng(2)
    re = rng.standard_normal((batch, n)).astype(np.float32)
    im = rng.standard_normal((batch, n)).astype(np.float32)
    got_r, got_i = fft_stockham(jnp.asarray(re), jnp.asarray(im))
    want = np.fft.fft(re + 1j * im, axis=-1)
    np.testing.assert_allclose(np.asarray(got_r), want.real,
                               rtol=1e-4, atol=1e-3 * np.sqrt(n))
    np.testing.assert_allclose(np.asarray(got_i), want.imag,
                               rtol=1e-4, atol=1e-3 * np.sqrt(n))


@pytest.mark.parametrize("n", [16, 128])
def test_fft_stockham_roundtrip(n):
    rng = np.random.default_rng(3)
    x = (rng.standard_normal((4, n)) + 1j * rng.standard_normal((4, n)))
    y = ops.fft1d(jnp.asarray(x, jnp.complex64))
    back = ops.fft1d(y, inverse=True)
    np.testing.assert_allclose(np.asarray(back), x, rtol=1e-4, atol=1e-4)


def test_stockham_matches_algorithm_reference():
    """Kernel == the numpy mirror of the same algorithm (exact structure)."""
    rng = np.random.default_rng(4)
    re = rng.standard_normal((3, 64)).astype(np.float32)
    im = rng.standard_normal((3, 64)).astype(np.float32)
    got_r, got_i = fft_stockham(jnp.asarray(re), jnp.asarray(im))
    want = ref.stockham_fft_np(re, im)
    np.testing.assert_allclose(np.asarray(got_r), want.real, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_i), want.imag, rtol=1e-4,
                               atol=1e-4)


def test_green_multiply_complex_matches_direct():
    rng = np.random.default_rng(5)
    f = (rng.standard_normal((6, 4, 128)) +
         1j * rng.standard_normal((6, 4, 128))).astype(np.complex64)
    g = rng.standard_normal((6, 4, 128)).astype(np.float32)
    got = ops.green_multiply(jnp.asarray(f), jnp.asarray(g), 0.25)
    np.testing.assert_allclose(np.asarray(got), f * g * 0.25, rtol=2e-6,
                               atol=1e-6)


def test_green_multiply_f64_preserves_precision():
    rng = np.random.default_rng(8)
    f = (rng.standard_normal((3, 64)) + 1j * rng.standard_normal((3, 64)))
    g = rng.standard_normal((3, 64))
    got = ops.green_multiply(jnp.asarray(f), jnp.asarray(g))
    assert np.asarray(got).dtype == np.complex128
    np.testing.assert_allclose(np.asarray(got), f * g, rtol=1e-14, atol=1e-14)


@pytest.mark.parametrize("batch", [1, 3])
@pytest.mark.parametrize("complex_field", [True, False])
def test_green_multiply_batched_shares_green_plane(batch, complex_field):
    """(B, *spec) field against ONE (*spec) Green: the kernel grids over
    the batch instead of broadcasting the Green into an HBM copy, and
    matches the broadcasted direct product."""
    rng = np.random.default_rng(9)
    shp = (4, 6, 128)
    if complex_field:
        f = (rng.standard_normal((batch,) + shp)
             + 1j * rng.standard_normal((batch,) + shp)).astype(np.complex64)
    else:
        f = rng.standard_normal((batch,) + shp).astype(np.float32)
    g = rng.standard_normal(shp).astype(np.float32)
    got = ops.green_multiply(jnp.asarray(f), jnp.asarray(g), 0.5)
    np.testing.assert_allclose(np.asarray(got), f * g * 0.5, rtol=2e-6,
                               atol=1e-6)


def test_spectral_scale_batched_grid():
    rng = np.random.default_rng(10)
    re, im = (rng.standard_normal((3, 16, 256)).astype(np.float32)
              for _ in range(2))
    g = rng.standard_normal((16, 256)).astype(np.float32)
    got_r, got_i = spectral_scale(jnp.asarray(re), jnp.asarray(im),
                                  jnp.asarray(g), 0.37)
    np.testing.assert_allclose(np.asarray(got_r), re * g * 0.37, rtol=2e-6,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_i), im * g * 0.37, rtol=2e-6,
                               atol=1e-6)


@pytest.mark.parametrize("n", [16, 64, 256])
def test_rfft_pallas_matches_jnp(n):
    rng = np.random.default_rng(6)
    x = rng.standard_normal((5, n))
    got = ops.rfft_pallas(jnp.asarray(x))
    want = np.fft.rfft(x, axis=-1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-10,
                               atol=1e-10 * np.sqrt(n))


@pytest.mark.parametrize("n", [16, 128])
def test_irfft_pallas_roundtrip(n):
    rng = np.random.default_rng(7)
    x = rng.standard_normal((4, n))
    half = ops.rfft_pallas(jnp.asarray(x))
    back = ops.irfft_pallas(half, n)
    np.testing.assert_allclose(np.asarray(back), x, rtol=1e-10, atol=1e-10)
    want = np.fft.irfft(np.asarray(half), n=n, axis=-1)
    np.testing.assert_allclose(np.asarray(back), want, rtol=1e-10, atol=1e-10)


def test_post_twiddle_matches_reference():
    rng = np.random.default_rng(9)
    re = rng.standard_normal((7, 33))
    im = rng.standard_normal((7, 33))
    a = np.cos(np.linspace(0, 2, 33))
    b = -np.sin(np.linspace(0, 2, 33))
    got = ops.post_twiddle(jnp.asarray(re), jnp.asarray(im), a, b)
    np.testing.assert_allclose(np.asarray(got), a * re + b * im,
                               rtol=1e-12, atol=1e-12)
