"""Launch layer: HLO stats parsing, scan-undercount rationale, dry-run cell."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch import hlo_stats

HLO_SAMPLE = """
ENTRY %main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[256,128]{1,0} all-gather(f32[16,128]{1,0} %p0), dims={0}
  %ar = bf16[64]{0} all-reduce(bf16[64]{0} %p0x), to_apply=%add
  %a2a-start = f32[8,32]{1,0} all-to-all-start(f32[8,32]{1,0} %x)
  %a2a-done = f32[8,32]{1,0} all-to-all-done(%a2a-start)
  %cp = u8[1024]{0} collective-permute(u8[1024]{0} %y)
  %a2at = (c64[9,8,4]{2,1,0}, c64[9,8,4]{2,1,0}) all-to-all(%f1, %f2), channel_id=1
  %dot = f32[16,16]{1,0} dot(f32[16,8] %a, f32[8,16] %b)
}
"""


def test_collective_stats_parsing():
    st = hlo_stats.collective_stats(HLO_SAMPLE)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 16 * 128 * 4
    assert st["all-reduce"]["bytes"] == 64 * 2
    assert st["all-to-all"]["count"] == 2          # start counted once
    # inline-operand form + tuple-result form (c64 = 8 bytes/elem)
    assert st["all-to-all"]["bytes"] == 8 * 32 * 4 + 2 * 9 * 8 * 4 * 8
    assert st["collective-permute"]["bytes"] == 1024
    assert st["total_count"] == 5


def test_fft_flops_parsing():
    txt = ("%fft.1 = c64[9,8,1536]{2,1,0} fft(%x), fft_type=FFT, "
           "fft_length={1536}")
    import math
    want = 5.0 * 9 * 8 * 1536 * math.log2(1536)
    assert hlo_stats.fft_flops(txt) == pytest.approx(want)


def test_cost_analysis_undercounts_scan():
    """The documented XLA behaviour that motivates flops_probe."""
    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y

    def unrolled(x, ws):
        for i in range(8):
            x = jnp.tanh(x @ ws[i])
        return x

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    cs = jax.jit(scanned).lower(x, ws).compile().cost_analysis()
    cu = jax.jit(unrolled).lower(x, ws).compile().cost_analysis()
    # the scanned body is counted once -> ~8x undercount
    assert cu["flops"] > 6 * cs["flops"]


def test_model_flops_sane():
    from repro.configs import get_config
    from repro.launch.cells import model_flops, _active_params
    # qwen3-0.6b total params ~ 0.75B incl embeddings
    n = _active_params(get_config("qwen3-0.6b"))
    assert 0.4e9 < n < 1.2e9
    # moe active << total: 22B-ish active for qwen3-235b
    na = _active_params(get_config("qwen3-moe-235b-a22b"))
    assert 10e9 < na < 40e9
    assert model_flops(get_config("qwen3-0.6b"), 100, "train") == \
        pytest.approx(6 * n * 100)


@pytest.mark.slow
def test_dryrun_one_cell_subprocess(tmp_path):
    """End-to-end dry-run of one cell on the 512-device production mesh."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-0.6b",
         "--shape", "decode_32k", "--mesh", "single", "--out",
         str(tmp_path), "--tag", "t"],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(open(tmp_path / "t.jsonl").read().strip())
    assert rec["status"] == "ok", rec
    assert rec["n_chips"] == 256
    assert rec["roofline"]["t_compute_s"] > 0
    assert rec["cost"]["flops"] > 0
