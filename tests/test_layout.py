"""Layout-scheduled pipeline == baseline (moveaxis) pipeline, plus the
fused Pallas epilogues and radix-4 Stockham stages (DESIGN.md #9).

The layout-scheduling correctness net:

* property-based scheduled-vs-baseline solve equality over per-direction
  BC category mixes, CELL + NODE layouts, batched and unbatched, both
  doubling modes -- BIT-EXACT on the xla engine (relayouts only reorder
  rows; the per-row transform and pointwise math is identical);
* the same equality through the distributed pencil solver for all four
  comm strategies x both relayout folds (subprocess, 8 host devices);
* ``hlo_stats.transpose_stats`` on the lowered distributed solve: the
  scheduled pipeline emits ZERO standalone transposes between stages (the
  one relayout per direction change is fused into the topology switch),
  the baseline pipeline does not;
* the Pallas fused epilogues (post-twiddle and Green multiply running in
  the FFT's final-stage registers) against numpy oracles and against
  their unfused two-kernel paths;
* radix-4 Stockham stages == radix-2 == numpy, including the pruned
  zero-tail first stage and the inverse.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.bc import BCType, DataLayout
from repro.core.engine import (LayoutSchedule, build_schedule, relayout,
                               schedule_layouts, switch_layout, to_last)
from repro.core.solver import PoissonSolver, make_plan

U, P, E, O = BCType.UNB, BCType.PER, BCType.EVEN, BCType.ODD

CATS = {
    "unb": (U, U),
    "semi": (U, E),
    "per": (P, P),
    "sym": (E, O),
}


# -- layout schedule bookkeeping --------------------------------------------

def test_schedule_layouts_invariants():
    """Every stage keeps its active dim minor-most; every consecutive pair
    of layouts is exactly one switch_layout step; bwd[0] reuses the
    spectral layout."""
    for order in ((0, 1, 2), (2, 0, 1), (1, 2, 0), (0, 2, 1)):
        lay = schedule_layouts(order, 3)
        assert isinstance(lay, LayoutSchedule)
        for i, d in enumerate(order):
            assert lay.fwd[i][-1] == d, (order, i)
        rev = tuple(reversed(order))
        for i, d in enumerate(rev):
            assert lay.bwd[i][-1] == d, (order, i)
        assert lay.bwd[0] == lay.spectral == lay.fwd[-1]
        for prev, (a, b) in zip(lay.fwd, zip(order, order[1:])):
            nxt = switch_layout(prev, a, b)
            assert nxt[0] == a and nxt[-1] == b


def test_order_policy_minimizes_edge_relayouts():
    """Single-category plans pick the order whose pipeline starts AND ends
    in the user's natural layout; mixed plans keep the historical order
    (ties break lexicographically)."""
    nat = (0, 1, 2)
    for bcs in (((P, P),) * 3, ((U, U),) * 3):
        plan = make_plan((8,) * 3, 1.0, bcs)
        lay = schedule_layouts(plan.order, 3)
        assert lay.fwd[0] == nat and lay.bwd[-1] == nat, plan.order
        assert make_plan((8,) * 3, 1.0, bcs,
                         order_policy="natural").order == nat
    # mixed sym+dft: historical order survives (it is already minimal)
    plan = make_plan((8,) * 3, 1.0, ((E, E), (O, E), (P, P)))
    assert plan.order == (0, 1, 2)


def test_relayout_roundtrip_and_batch_axes():
    x = jnp.arange(2 * 3 * 4 * 5).reshape(2, 3, 4, 5)
    src, dst = (0, 1, 2), (2, 0, 1)
    y = relayout(x, src, dst)
    assert y.shape == (2, 5, 3, 4)          # leading batch axis untouched
    assert np.array_equal(np.asarray(relayout(y, dst, src)), np.asarray(x))
    assert relayout(x, src, src) is x
    assert to_last((0, 1, 2), 1) == (0, 2, 1)


def test_r2c_follows_the_scheduled_order():
    """The r2c direction is the first EXECUTED DFT dim, not the lowest
    index -- the spectral storage follows the scheduled order."""
    plan = make_plan((8,) * 3, 1.0, ((P, P),) * 3)
    d0 = plan.order[0]
    assert plan.dirs[d0].dft == "r2c"
    assert all(plan.dirs[d].dft == "c2c" for d in plan.order[1:])


# -- scheduled == baseline, single process ----------------------------------

def _solvers(cats, layout, engine, doubling="deferred", n=4):
    bcs = tuple(CATS[c] for c in cats)
    kw = dict(layout=layout, engine=engine, doubling=doubling)
    a = PoissonSolver((n,) * 3, 1.0, bcs, relayout="scheduled", **kw)
    b = PoissonSolver((n,) * 3, 1.0, bcs, relayout="baseline", **kw)
    return a, b


@settings(max_examples=14, deadline=None)
@given(c0=st.sampled_from(list(CATS)), c1=st.sampled_from(list(CATS)),
       c2=st.sampled_from(list(CATS)),
       layout=st.sampled_from(["CELL", "NODE"]),
       doubling=st.sampled_from(["deferred", "upfront"]),
       batched=st.booleans(), seed=st.integers(min_value=0, max_value=2**31))
def test_scheduled_equals_baseline_xla_bitexact(c0, c1, c2, layout, doubling,
                                                batched, seed):
    """Any BC mix, any layout, batched or not, both doubling modes:
    layout-scheduled == baseline, bit for bit, on the xla engine -- the
    relayouts only reorder rows, every transform sees the same values."""
    a, b = _solvers((c0, c1, c2), DataLayout[layout], "xla", doubling)
    rng = np.random.default_rng(seed)
    shape = ((2,) + a.input_shape) if batched else a.input_shape
    f = jnp.asarray(rng.standard_normal(shape))
    ua = np.asarray(a.solve(f))
    ub = np.asarray(b.solve(f))
    assert np.array_equal(ua, ub), np.max(np.abs(ua - ub))


@settings(max_examples=4, deadline=None)
@given(c0=st.sampled_from(["unb", "per", "sym"]),
       layout=st.sampled_from(["CELL", "NODE"]),
       seed=st.integers(min_value=0, max_value=2**31))
def test_scheduled_equals_baseline_pallas(c0, layout, seed):
    """On the pallas engine the scheduled pipeline swaps in the FUSED
    epilogue kernels, so the comparison is to roundoff, not bits."""
    a, b = _solvers((c0, "per", "unb"), DataLayout[layout], "pallas", n=8)
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.standard_normal(a.input_shape))
    np.testing.assert_allclose(np.asarray(a.solve(f)),
                               np.asarray(b.solve(f)),
                               rtol=1e-9, atol=1e-11)


def test_order_policies_agree_to_roundoff():
    """order_policy="layout" (reordered execution) solves the same problem
    as the historical natural order to fp accuracy."""
    bcs = (CATS["unb"],) * 3
    a = PoissonSolver((8,) * 3, 1.0, bcs)
    b = PoissonSolver((8,) * 3, 1.0, bcs, order_policy="natural")
    assert a.plan.order != b.plan.order
    f = jnp.asarray(np.random.default_rng(0).standard_normal(a.input_shape))
    np.testing.assert_allclose(np.asarray(a.solve(f)),
                               np.asarray(b.solve(f)),
                               rtol=1e-12, atol=1e-13)


# -- distributed equality + lowered-HLO transpose census --------------------

_DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings
warnings.simplefilter("ignore")
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core.bc import BCType, DataLayout
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver
from repro.launch.hlo_stats import transpose_stats

E, O, P, U = BCType.EVEN, BCType.ODD, BCType.PER, BCType.UNB
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
CASES = [
    (((E, E), (O, E), (P, P)), DataLayout.NODE, "deferred"),
    (((U, U), (U, U), (U, U)), DataLayout.CELL, "deferred"),
    (((U, E), (U, U), (O, U)), DataLayout.CELL, "upfront"),
    (((P, P), (P, P), (P, P)), DataLayout.CELL, "deferred"),
]
n = 16
for bcs, layout, doubling in CASES:
    for strat in ("a2a", "pipelined", "fused", "overlap"):
        for fold in ("pack", "unpack"):
            kw = dict(layout=layout, mesh=mesh, dtype=jnp.float64,
                      doubling=doubling, comm=CommConfig(strat, 2, fold))
            sb = DistributedPoissonSolver((n, n, n), 1.0, bcs,
                                          relayout="baseline", **kw)
            ss = DistributedPoissonSolver((n, n, n), 1.0, bcs,
                                          relayout="scheduled", **kw)
            f = rng.standard_normal(sb.input_shape)
            err = np.max(np.abs(np.asarray(sb.solve(f))
                                - np.asarray(ss.solve(f))))
            assert err == 0.0, (strat, fold, layout.name, doubling, err)
            fb = np.stack([f, -0.5 * f, 2.0 * f, 0.25 * f])
            errb = np.max(np.abs(np.asarray(sb.solve(fb))
                                 - np.asarray(ss.solve(fb))))
            assert errb == 0.0, (strat, fold, "batch", errb)

# lowered-HLO transpose census: the acceptance probe of DESIGN.md #9
P2 = (P, P)
for fold in ("pack", "unpack"):
    ss = DistributedPoissonSolver((16,) * 3, 1.0, (P2, P2, P2), mesh=mesh,
                                  comm=CommConfig("a2a", 1, fold),
                                  relayout="scheduled", lazy_green=True)
    ts = transpose_stats(ss.lower().as_text())
    assert ts["standalone"] == 0, (fold, ts)
    assert ts["collectives"] == 4 and ts["switch_fused"] <= 4, (fold, ts)
    # single-category order (2, 0, 1): both edge adapters are identity
    assert ts["edge"] == 0, (fold, ts)
sb = DistributedPoissonSolver((16,) * 3, 1.0, (P2, P2, P2), mesh=mesh,
                              comm=CommConfig("a2a"), relayout="baseline",
                              order_policy="natural", lazy_green=True)
tb = transpose_stats(sb.lower().as_text())
assert tb["standalone"] > 0, tb   # the census must discriminate

# chunked overlap keeps its interleave AND the zero-standalone property
so = DistributedPoissonSolver((16,) * 3, 1.0, (P2, P2, P2), mesh=mesh,
                              comm=CommConfig("overlap", 4),
                              relayout="scheduled", lazy_green=True)
ts = transpose_stats(so.lower().as_text())
assert ts["standalone"] == 0, ts
assert ts["collectives"] == 16, ts

# the autotune key carries the layout choice: same plan, different
# relayout/order must never replay each other's cached winner
ka = sb.autotune_key()
kb = ss.autotune_key()
assert ka != kb
assert ("relayout", "scheduled") in kb and ("relayout", "baseline") in ka
print("OK")
"""


def test_distributed_scheduled_equals_baseline_and_hlo_census():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_COMM_CACHE", None)
    out = subprocess.run(
        [sys.executable, "-c", _DIST_SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# -- Pallas fused epilogues vs numpy oracles --------------------------------

@pytest.mark.parametrize("n,start", [(16, 0), (64, 1), (128, 5)])
def test_rfft_twiddle_matches_numpy(n, start):
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    x = rng.standard_normal((7, n)).astype(np.float32)
    k = n // 2 - start
    a = rng.standard_normal(k)
    b = rng.standard_normal(k)
    got = np.asarray(ops.rfft_twiddle(jnp.asarray(x), a, b, start=start))
    F = np.fft.fft(x, axis=-1)
    want = a * F.real[:, start:start + k] + b * F.imag[:, start:start + k]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_rfft_twiddle_pruned_zero_tail():
    """pad_to composes the Hockney skip-zero first stage with the fused
    post-twiddle epilogue."""
    from repro.kernels import ops
    rng = np.random.default_rng(1)
    n = 32
    x = rng.standard_normal((5, n)).astype(np.float32)
    a = rng.standard_normal(n + 1)
    b = rng.standard_normal(n + 1)
    got = np.asarray(ops.rfft_twiddle(jnp.asarray(x), a, b, pad_to=2 * n))
    F = np.fft.fft(np.concatenate([x, np.zeros_like(x)], axis=-1), axis=-1)
    want = a * F.real[:, :n + 1] + b * F.imag[:, :n + 1]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("batched", [False, True])
def test_fft_green_epilogues_match_numpy(batched):
    from repro.kernels import ops
    rng = np.random.default_rng(2)
    n, rows = 32, 6
    B = 3 if batched else 1
    z = (rng.standard_normal((B * rows, n))
         + 1j * rng.standard_normal((B * rows, n))).astype(np.complex64)
    g_full = rng.standard_normal((rows, n)).astype(np.float32)
    g_half = rng.standard_normal((rows, n // 2 + 1)).astype(np.float32)
    got = np.asarray(ops.fft1d_green(jnp.asarray(z), jnp.asarray(g_full)))
    want = np.fft.fft(z, axis=-1) * np.tile(g_full, (B, 1))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    xr = rng.standard_normal((B * rows, n)).astype(np.float32)
    got = np.asarray(ops.rfft_green(jnp.asarray(xr), jnp.asarray(g_half)))
    want = np.fft.rfft(xr, axis=-1) * np.tile(g_half, (B, 1))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_fused_r2r_matches_unfused_and_scipy():
    """dct2/dst2/dct1 through the pallas engine now run the fused
    rfft+twiddle kernel; they must still match scipy and the xla path."""
    import scipy.fft as sfft
    from repro.core import transforms as tr
    from repro.core.engine import TransformEngine
    rng = np.random.default_rng(3)
    eng = TransformEngine("pallas")
    # widths chosen so the fused kernel actually engages: dct2/dst2 extend
    # to 2M (M=32 -> 64), dct1 to 2(M-1) (M=33 -> 64)
    for name, fn, m, sref in (("dct2", tr.dct2, 32, lambda v: sfft.dct(v, 2)),
                              ("dst2", tr.dst2, 32, lambda v: sfft.dst(v, 2)),
                              ("dct1", tr.dct1, 33, lambda v: sfft.dct(v, 1))):
        x = rng.standard_normal((5, m))
        fused = np.asarray(fn(jnp.asarray(x), engine=eng))
        unfused = np.asarray(fn(jnp.asarray(x), engine=None))
        np.testing.assert_allclose(fused, sref(x), rtol=1e-8, atol=1e-8)
        np.testing.assert_allclose(fused, unfused, rtol=1e-8, atol=1e-8)
        # the pallas path must actually be the fused single kernel
        trace = str(jax.make_jaxpr(
            lambda v: fn(v, engine=eng))(jnp.asarray(x)))
        assert trace.count("pallas_call") == 1, name


def test_fwd_last_green_fuses_and_matches_unfused():
    """The schedule-level green fusion hook: fused == transform + multiply,
    and the fused trace contains ONE pallas_call where the unfused path
    has two (FFT then spectral_scale)."""
    plan = make_plan((8,) * 3, 1.0, ((P, P),) * 3)
    sched = build_schedule(plan, "pallas")
    d = plan.order[-1]
    assert sched.can_fuse_green(d)
    rng = np.random.default_rng(4)
    x = jnp.asarray((rng.standard_normal((8, 8, 8))
                     + 1j * rng.standard_normal((8, 8, 8))),
                    dtype=jnp.complex64)
    green = jnp.asarray(rng.standard_normal((8, 8, plan.dirs[d].n_out)),
                        dtype=jnp.float32)
    fused = np.asarray(sched.fwd_last_green(x, d, green))
    unfused = np.asarray(sched.green_multiply(sched.fwd_last(x, d), green))
    np.testing.assert_allclose(fused, unfused, rtol=1e-4, atol=1e-4)
    trace = str(jax.make_jaxpr(
        lambda v: sched.fwd_last_green(v, d, green))(x))
    assert trace.count("pallas_call") == 1


# -- radix-4 Stockham stages ------------------------------------------------

@pytest.mark.parametrize("n", [2, 4, 8, 32, 128, 512])
def test_radix4_matches_radix2_and_numpy(n):
    from repro.kernels.fft_stockham import fft_stockham, stage_count
    rng = np.random.default_rng(5)
    re = rng.standard_normal((5, n)).astype(np.float32)
    im = rng.standard_normal((5, n)).astype(np.float32)
    want = np.fft.fft(re + 1j * im, axis=-1)
    tol = 1e-3 * np.sqrt(n)
    for mr in (2, 4):
        gr, gi = fft_stockham(jnp.asarray(re), jnp.asarray(im), max_radix=mr)
        np.testing.assert_allclose(np.asarray(gr), want.real, atol=tol)
        np.testing.assert_allclose(np.asarray(gi), want.imag, atol=tol)
        br, bi = fft_stockham(jnp.asarray(want.real.astype(np.float32)),
                              jnp.asarray(want.imag.astype(np.float32)),
                              inverse=True, max_radix=mr)
        np.testing.assert_allclose(np.asarray(br), re, atol=1e-3)
    k = int(np.log2(n))
    assert stage_count(n, 2) == k
    assert stage_count(n, 4) == k // 2 + k % 2


def test_radix4_pruned_zero_tail():
    from repro.kernels.fft_stockham import fft_stockham
    rng = np.random.default_rng(6)
    n = 64
    re = rng.standard_normal((4, n)).astype(np.float32)
    im = rng.standard_normal((4, n)).astype(np.float32)
    zre = np.concatenate([re, np.zeros_like(re)], axis=-1)
    zim = np.concatenate([im, np.zeros_like(im)], axis=-1)
    want = np.fft.fft(zre + 1j * zim, axis=-1)
    gr, gi = fft_stockham(jnp.asarray(re), jnp.asarray(im), pad_to=2 * n)
    np.testing.assert_allclose(np.asarray(gr), want.real, atol=1e-3 * n**0.5)
    np.testing.assert_allclose(np.asarray(gi), want.imag, atol=1e-3 * n**0.5)
