"""Properties of the Appendix-A invertible balanced partition."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI images without hypothesis: deterministic local shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import partition as pt


def test_paper_example():
    """N=32, P=7 -> [5,5,5,5,4,4,4]; i=14 -> rank 2; i=27 -> rank 5."""
    np.testing.assert_array_equal(pt.counts(32, 7), [5, 5, 5, 5, 4, 4, 4])
    assert pt.index_to_rank(32, 7, 14) == 2
    assert pt.index_to_rank(32, 7, 27) == 5


def test_regular_case_more_homogeneous():
    """N=32, P=6: excess spread over the range, not piled on the front."""
    c = pt.counts(32, 6)
    assert c.sum() == 32
    assert c.max() - c.min() <= 1
    # excess data are strided (groups of S=3), not the first R ranks
    assert list(c) == [5, 5, 6, 5, 5, 6]


@settings(max_examples=300, deadline=None)
@given(n=st.integers(1, 5000), p=st.integers(1, 600))
def test_partition_is_a_partition(n, p):
    c = pt.counts(n, p)
    assert c.sum() == n
    assert (c >= 0).all()
    assert c.max() - c.min() <= 1  # balanced


@settings(max_examples=300, deadline=None)
@given(n=st.integers(1, 3000), p=st.integers(1, 300))
def test_inverse_consistency(n, p):
    """index_to_rank is the exact inverse of the rank->range map."""
    ranks = np.arange(p)
    starts = pt.rank_first_index(n, p, ranks)
    ends = pt.rank_first_index(n, p, ranks + 1)
    idx = np.arange(n)
    owner = pt.index_to_rank(n, p, idx)
    assert ((idx >= starts[owner]) & (idx < ends[owner])).all()


@settings(max_examples=100, deadline=None)
@given(p=st.integers(1, 500), r=st.integers(0, 499))
def test_send_order_is_permutation_starting_at_neighbor(p, r):
    r = r % p
    order = pt.send_order(p, r)
    assert sorted(order) == list(range(p))
    assert order[0] == (r + 1) % p
