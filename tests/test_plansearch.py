"""The plan-space search test net (DESIGN.md #12).

Three layers of evidence that the cost-model-guided search is safe to run
by default:

* the analytic predictor (``plan.costmodel.predict_bytes``) matches the
  HLO-measured per-collective bytes BIT-FOR-BIT on compiled plans, across
  strategies, chunk counts, folds, relayouts, doubling modes, layouts,
  meshes (2-D and degenerate slabs) and batch shapes -- including the
  PR-4 valid-extent crops of deferred Hockney doubling;
* a brute-force oracle: the guided shortlist's measured winner stays
  within 10% of the exhaustive sweep's winner (head-to-head re-timed when
  they differ) while wall-clock timing >= 5x fewer candidates;
* the cache/pruning plumbing: schema-2 JSON migration of legacy flat
  files (warned once, counted in ``census["migrated"]``) and the
  prime-extent padding prune that keeps doomed zero-padded chunk
  candidates out of the timed frontier.
"""
import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.core.bc import BCType, DataLayout
from repro.core.comm import (CommConfig, autotune_candidates, autotune_comm,
                             cache_load_entries, cache_store_entry,
                             cfg_label, clear_autotune_cache, label_to_cfg,
                             reset_warn_once)
from repro.core.green import GreenKind
from repro.core.solver import make_plan
from repro.plan import (CostModel, PlanPoint, PlanSpace, SHORTLIST_DIVISOR,
                        guided_comm_candidates, mesh_shapes_for,
                        predict_bytes)

P, U, E, O = BCType.PER, BCType.UNB, BCType.EVEN, BCType.ODD


def _run_script(script, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    # a developer's persisted caches must not leak into the sweeps
    env.pop("REPRO_COMM_CACHE", None)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=timeout,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# -- space enumeration -------------------------------------------------------

def test_comm_space_matches_brute_grid():
    """The declarative comm sub-space enumerates exactly the candidates the
    historical brute sweep timed (same labels, same order of magnitude)."""
    sp = PlanSpace.comm(max_chunks=4, folds=("pack", "unpack"))
    cfgs = sp.comm_configs()
    brute = autotune_candidates(4, folds=("pack", "unpack"))
    assert set(map(cfg_label, cfgs)) == set(map(cfg_label, brute))
    assert len(cfgs) == 12


def test_space_validity_constraints():
    # monolithic strategies never carry chunk knobs
    for pt in PlanSpace.comm(folds=("pack",), batched=True).points():
        if pt.strategy in ("a2a", "fused"):
            assert pt.n_chunks == 1 and pt.chunk_axis == "auto"
    # chunk_axis="grid" exists only in batched spaces
    assert all(pt.chunk_axis == "auto"
               for pt in PlanSpace.comm(folds=("pack",)).points())
    assert any(pt.chunk_axis == "grid"
               for pt in PlanSpace.comm(folds=("pack",),
                                        batched=True).points())
    # radix 2 is a Pallas-only dimension
    assert all(pt.radix == 4
               for pt in PlanSpace.full(8, engine="xla").points())
    assert any(pt.radix == 2
               for pt in PlanSpace.full(8, engine="pallas").points())
    # fold="unpack" only under the scheduled relayout
    for pt in PlanSpace.full(8, engine="xla").points():
        if pt.relayout == "baseline":
            assert pt.fold == "pack"


def test_mesh_shapes_squarest_first():
    assert mesh_shapes_for(8) == ((2, 4), (4, 2), (1, 8), (8, 1))
    assert mesh_shapes_for(8, include_slabs=False) == ((2, 4), (4, 2))
    assert (1, 8) not in mesh_shapes_for(8, include_slabs=False)


def test_plan_point_label_and_dict_round_trip():
    for pt in PlanSpace.full(8, engine="pallas").points():
        assert PlanPoint.fromdict(pt.asdict()) == pt
    # comm sub-labels parse back through the comm-level parser
    for cfg in PlanSpace.comm(folds=("pack", "unpack"),
                              batched=True).comm_configs():
        assert label_to_cfg(cfg_label(cfg)) == cfg


# -- predictor vs HLO (the bit-for-bit property net) -------------------------

_PREDICT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core.bc import BCType, DataLayout
from repro.core.comm import CommConfig
from repro.distributed.pencil import DistributedPoissonSolver
from repro.launch.hlo_stats import comm_bytes_stats
from repro.plan.costmodel import predict_bytes

E, O, P, U = BCType.EVEN, BCType.ODD, BCType.PER, BCType.UNB
CELL, NODE = DataLayout.CELL, DataLayout.NODE
# (n, bcs, layout, mesh, comm, batch, doubling, relayout, order, dtype):
# a deterministic sample of the space -- every strategy, both folds, both
# relayouts, both doubling modes, CELL and NODE, 2-D and slab meshes,
# dividing and non-dividing batch/chunk combinations
cases = [
    (16, ((P,P),)*3, CELL, (2,4), CommConfig("a2a",1), None,
     "deferred", "scheduled", "layout", jnp.float64),
    (16, ((P,P),)*3, CELL, (2,4), CommConfig("fused",1), None,
     "deferred", "scheduled", "layout", jnp.float32),
    (16, ((U,U),)*3, CELL, (2,4), CommConfig("pipelined",2), None,
     "deferred", "scheduled", "layout", jnp.float64),
    (16, ((U,U),)*3, CELL, (2,4), CommConfig("pipelined",2), None,
     "upfront", "scheduled", "layout", jnp.float64),
    (12, ((E,E),(O,E),(P,P)), NODE, (4,2), CommConfig("overlap",4,"unpack"),
     None, "deferred", "scheduled", "layout", jnp.float32),
    (16, ((U,U),(P,P),(U,U)), CELL, (1,8), CommConfig("overlap",2), None,
     "upfront", "baseline", "natural", jnp.float64),
    (16, ((U,U),)*3, NODE, (8,1), CommConfig("a2a",1), None,
     "deferred", "scheduled", "natural", jnp.float64),
    (16, ((P,P),)*3, CELL, (2,4), CommConfig("pipelined",4), 3,
     "deferred", "scheduled", "layout", jnp.float64),   # B does not divide
    (16, ((P,P),)*3, CELL, (2,4), CommConfig("overlap",2), 4,
     "deferred", "scheduled", "layout", jnp.float64),   # B divides: free axis
    (16, ((P,P),)*3, CELL, (2,4), CommConfig("pipelined",4,"pack","grid"), 4,
     "deferred", "scheduled", "layout", jnp.float64),   # pinned grid axis
    (17, ((P,P),)*3, CELL, (2,4), CommConfig("pipelined",2), None,
     "deferred", "scheduled", "layout", jnp.float32),   # prime extents
    (16, ((U,U),)*3, NODE, (2,4), CommConfig("overlap",4,"unpack"), 2,
     "deferred", "scheduled", "layout", jnp.float64),
]
fails = 0
for (n, bcs, lay, ms, cfg, B, dbl, rel, op, dt) in cases:
    mesh = jax.make_mesh(ms, ("data", "model"))
    ds = DistributedPoissonSolver((n,n,n), 1.0, bcs, layout=lay, mesh=mesh,
                                  comm=cfg, lazy_green=True, dtype=dt,
                                  doubling=dbl, relayout=rel,
                                  order_policy=op)
    text = ds.lower(batch=B, local_batch=B is not None).as_text()
    got = [p["bytes"] for p in comm_bytes_stats(text)["per_collective"]]
    want = predict_bytes(ds.plan, ms[0], ms[1], dt, cfg, batch=B)
    tag = (f"n={n} {lay.name} mesh={ms} {cfg.strategy}:{cfg.n_chunks}:"
           f"{cfg.fold}:{cfg.chunk_axis} B={B} {dbl}/{rel}/{op}")
    if got != want:
        fails += 1
        print("MISMATCH", tag)
        print("  measured ", got)
        print("  predicted", want)
assert fails == 0, f"{fails} predictor/HLO mismatches"
print("PREDICTOR_OK")
"""


def test_predictor_matches_hlo_bytes_bit_for_bit():
    """``predict_bytes`` == per-collective HLO measurement, exactly, on
    every sampled point of the space (no compile: lowered text only)."""
    out = _run_script(_PREDICT_SCRIPT)
    assert "PREDICTOR_OK" in out, out


# -- brute-force oracle ------------------------------------------------------

_ORACLE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp
from repro.core.bc import BCType, DataLayout
from repro.core.comm import autotune_candidates, cfg_label
from repro.distributed.pencil import DistributedPoissonSolver
from repro.plan.search import guided_comm_candidates

P, U = BCType.PER, BCType.UNB
cases = [(16, ((P, P),) * 3, (2, 4)),
         (16, ((U, U),) * 3, (1, 8)),
         (16, ((P, P),) * 3, (4, 2)),
         (24, ((U, U),) * 3, (2, 4))]
for n, bcs, (p1, p2) in cases:
    mesh = jax.make_mesh((p1, p2), ("data", "model"))
    ds = DistributedPoissonSolver((n,) * 3, 1.0, bcs,
                                  layout=DataLayout.CELL, mesh=mesh,
                                  dtype=jnp.float32)
    time_cfg = ds.comm_time_fn(reps=3)
    brute = autotune_candidates(4, folds=("pack", "unpack"))
    census = {}
    guided = guided_comm_candidates(ds.plan, p1, p2, ds.dtype,
                                    folds=("pack", "unpack"),
                                    relayout=ds.relayout, census=census)
    # the >= 5x census gate: guided may wall-clock time at most a fifth
    # of what the exhaustive oracle times
    assert 5 * len(guided) <= len(brute), (
        f"n={n} mesh=({p1},{p2}): guided times {len(guided)} of "
        f"{len(brute)}")
    memo = {}
    def timed(cfg):
        lbl = cfg_label(cfg)
        if lbl not in memo:
            memo[lbl] = time_cfg(cfg)
        return memo[lbl]
    bt = {cfg_label(c): timed(c) for c in brute}
    gt = {cfg_label(c): timed(c) for c in guided}
    bw, gw = min(bt, key=bt.get), min(gt, key=gt.get)
    if bw == gw:
        print(f"case n={n} mesh=({p1},{p2}): winners identical ({bw}), "
              f"timed {len(gt)}/{len(bt)}")
        continue
    # winners differ: interleaved head-to-head re-timing (same process
    # state, alternating order) for a fair 10%-regret comparison; the
    # 150us absolute floor keeps sub-ms 16^3 CPU solves -- where 10% is
    # below OS scheduler/timer noise -- from flaking the relative gate
    by = {cfg_label(c): c for c in brute}
    tb = tg = float("inf")
    for r in range(8):
        for lbl in ((bw, gw) if r % 2 == 0 else (gw, bw)):
            t = time_cfg(by[lbl])
            if lbl == bw:
                tb = min(tb, t)
            else:
                tg = min(tg, t)
    ratio = tg / tb
    print(f"case n={n} mesh=({p1},{p2}): brute={bw} guided={gw} "
          f"ratio={ratio:.3f}, timed {len(gt)}/{len(bt)}")
    assert tg <= 1.10 * tb + 150e-6, (
        f"n={n} mesh=({p1},{p2}): guided winner {gw} is {ratio:.2f}x the "
        f"brute winner {bw} -- regret bound exceeded")
print("ORACLE_OK")
"""


def test_guided_within_10pct_of_brute_oracle():
    """Exhaustive sweep vs guided shortlist on 16^3/24^3 over (2,4), (1,8)
    and (4,2) meshes: the guided winner's measured time stays within 10%
    of the brute winner's (head-to-head re-timed when they differ) while
    timing >= 5x fewer candidates."""
    out = _run_script(_ORACLE_SCRIPT, timeout=1800)
    assert "ORACLE_OK" in out, out


# -- shortlist / padding-prune policy ---------------------------------------

def _plan(shape, bcs, layout=DataLayout.CELL, **kw):
    return make_plan(shape, 1.0, bcs, layout, GreenKind.CHAT2, **kw)


def test_guided_shortlist_is_frontier_sized():
    plan = _plan((16,) * 3, ((P, P),) * 3)
    census = {}
    short = guided_comm_candidates(plan, 2, 4, "float32",
                                   folds=("pack", "unpack"), census=census)
    assert census["space"] == 12
    live = census["space"] - len(census["pruned_padding"])
    assert len(short) == max(1, -(-live // SHORTLIST_DIVISOR))
    assert census["shortlist"] == [cfg_label(c) for c in short]
    # ranked by predicted cost: the shortlist head is the predictor's best
    best = min(census["predicted"], key=census["predicted"].get)
    assert census["shortlist"][0] == best


def test_padding_prune_prime_extent():
    """A prime grid extent (nothing divides the chunk axes) prunes every
    zero-padded chunked candidate that cannot beat the monolithic floor --
    the frontier never wastes wall-clock on doomed candidates."""
    plan = _plan((17,) * 3, ((P, P),) * 3)
    census = {}
    short = guided_comm_candidates(plan, 2, 4, "float32",
                                   folds=("pack", "unpack"), census=census)
    assert census["pruned_padding"], census
    assert not set(census["shortlist"]) & set(census["pruned_padding"])
    # the monolithic strategies survive and lead the frontier
    assert all(label_to_cfg(lbl).n_chunks == 1
               for lbl in census["shortlist"]), census["shortlist"]
    # a dividing in-block batch restores the free ("auto") chunk axis: no
    # default-axis candidate is padded any more, so only the explicitly
    # grid-pinned ones stay pruned
    census_b = {}
    guided_comm_candidates(plan, 2, 4, "float32", batch=8,
                           folds=("pack", "unpack"), census=census_b)
    assert all("ca=grid" in lbl for lbl in census_b["pruned_padding"]), \
        census_b["pruned_padding"]
    assert census_b["space"] > census["space"]  # + chunk_axis dimension


def test_predictor_prefers_fewer_collectives_at_small_scale():
    """Sanity on the cost model's shape: at tiny grids the per-collective
    alpha dominates, so monolithic plans must rank ahead of 4-way chunked
    ones; the byte totals are identical across folds."""
    plan = _plan((16,) * 3, ((U, U),) * 3)
    m = CostModel()
    mono, _ = m.comm_cost(plan, 2, 4, "float32", CommConfig("a2a", 1))
    chunk, _ = m.comm_cost(plan, 2, 4, "float32",
                           CommConfig("pipelined", 4))
    assert mono < chunk
    _, meta_p = m.comm_cost(plan, 2, 4, "float32",
                            CommConfig("overlap", 2, "pack"))
    _, meta_u = m.comm_cost(plan, 2, 4, "float32",
                            CommConfig("overlap", 2, "unpack"))
    assert meta_p["bytes"] == meta_u["bytes"]


def test_predict_bytes_slab_mesh_skips_unit_axis():
    """A 1-sized mesh axis lowers its switches to local reshapes -- no
    collective is emitted, and the predictor must agree."""
    plan = _plan((16,) * 3, ((P, P),) * 3)
    full = predict_bytes(plan, 2, 4, "float32", CommConfig("a2a", 1))
    slab = predict_bytes(plan, 1, 8, "float32", CommConfig("a2a", 1))
    assert len(full) == 4
    assert len(slab) == 2           # only the p2-axis switches ship bytes


# -- cache schema migration --------------------------------------------------

def test_cache_schema_v1_migrates_in_memory_and_rewrites_on_store(tmp_path):
    """A legacy flat (schema-1) cache file: entries are carried over in
    memory (fold defaulted, warned ONCE per file, counted in
    ``census["migrated"]``), replayed as autotune hits, and the next store
    rewrites the file as schema 2."""
    clear_autotune_cache()
    reset_warn_once()
    path = str(tmp_path / "comm_cache.json")
    cands = (CommConfig("a2a", 1), CommConfig("pipelined", 2))
    labels = tuple(cfg_label(c) for c in cands)
    timings = {"a2a:1": 1.0, "pipelined:2": 2.0}
    key = repr((("k1",), labels))
    # hand-write the legacy flat layout: key -> entry, no envelope
    legacy = {key: {"strategy": "pipelined", "n_chunks": 2,
                    "timings_us": {k: v * 1e6 for k, v in timings.items()}}}
    with open(path, "w") as fh:
        json.dump(legacy, fh)

    census = {}
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        entries = cache_load_entries(path, census=census)
        cache_load_entries(path, census={})         # second load: no re-warn
    assert census["migrated"] == 1
    assert entries[key]["fold"] == "pack"           # historical default
    msgs = [str(w.message) for w in rec if "legacy flat" in str(w.message)]
    assert len(msgs) == 1, msgs

    # the migrated entry is a live autotune hit: no timing sweep runs
    calls = []

    def timer(cfg):
        calls.append(cfg)
        return 1.0

    best = autotune_comm(("k1",), timer, candidates=cands, cache_path=path)
    assert best == CommConfig("pipelined", 2)
    assert calls == [], "migrated cache entry must skip the sweep"

    # storing rewrites the file as the current schema, preserving the
    # migrated entry next to the new one
    cache_store_entry(path, "other", {"strategy": "a2a", "n_chunks": 1,
                                      "fold": "pack"})
    with open(path) as fh:
        data = json.load(fh)
    assert data["schema"] == 2
    assert set(data["entries"]) == {key, "other"}
    assert data["entries"][key]["fold"] == "pack"
    # round trip: the rewritten file loads with zero migrations
    census2 = {}
    assert cache_load_entries(path, census=census2)
    assert census2["migrated"] == 0


def test_cache_unsupported_schema_ignored(tmp_path):
    reset_warn_once()
    path = str(tmp_path / "comm_cache.json")
    with open(path, "w") as fh:
        json.dump({"schema": 99, "entries": {"k": {}}}, fh)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert cache_load_entries(path) == {}
    assert any("unsupported schema" in str(w.message) for w in rec)


# -- wiring -------------------------------------------------------------------

def test_guided_is_the_default_everywhere():
    import inspect

    from repro.configs.flups_poisson import PoissonArchConfig
    from repro.distributed.pencil import DistributedPoissonSolver
    from repro.serve.server import PlanSpec

    sig = inspect.signature(DistributedPoissonSolver.__init__)
    assert sig.parameters["autotune_search"].default == "guided"
    assert PoissonArchConfig.__dataclass_fields__[
        "comm_autotune_search"].default == "guided"
    assert PlanSpec.__dataclass_fields__["search"].default == "guided"
    # and the serve key separates guided from brute pools
    spec_g = PlanSpec((8, 8, 8), ((P, P),) * 3)
    spec_b = PlanSpec((8, 8, 8), ((P, P),) * 3, search="brute")
    assert spec_g.key() != spec_b.key()


def test_search_plan_times_only_the_frontier_and_caches(tmp_path):
    """Plan-level search on the in-process device: the full space is
    predicted, only the shortlist is timed, and the winner round-trips
    through the schema-2 cache."""
    from repro.plan import search_plan

    cache = str(tmp_path / "plans.json")
    census = {}
    dec = search_plan((8,) * 3, 1.0, ((P, P),) * 3, mesh_shapes=((1, 1),),
                      cache_path=cache, census=census, reps=1)
    assert not dec.cached
    assert census["space"] > len(census["shortlist"])
    assert set(census["timed"]) <= set(census["shortlist"])
    assert dec.point.label() in census["timed"]
    with open(cache) as fh:
        data = json.load(fh)
    assert data["schema"] == 2 and len(data["entries"]) == 1

    census2 = {}
    dec2 = search_plan((8,) * 3, 1.0, ((P, P),) * 3, mesh_shapes=((1, 1),),
                       cache_path=cache, census=census2, reps=1)
    assert dec2.cached and dec2.point == dec.point
    # a different dtype is a different family: no replay
    census3 = {}
    dec3 = search_plan((8,) * 3, 1.0, ((P, P),) * 3, mesh_shapes=((1, 1),),
                       dtype=np.float64, cache_path=cache, census=census3,
                       reps=1)
    assert not dec3.cached
