"""Validation of the Poisson solver against the paper's analytical cases.

Section IV / Appendix B of the paper:
  A. symmetric + periodic BCs (even-even x, odd-even y, periodic z)
  B. fully unbounded
  C. two semi-unbounded + one fully unbounded

Convergence orders are asserted per Green's function kind (Figs 6-8).
Both layouts (cell/node) are exercised; the paper's validation uses the
node-centered layout.
"""
import numpy as np
import pytest

from repro.core.bc import BCType, DataLayout
from repro.core.green import GreenKind
from repro.core.solver import PoissonSolver

E, O, P, U = BCType.EVEN, BCType.ODD, BCType.PER, BCType.UNB
L = 1.0


def grids(n, layout):
    """Physical coordinates per direction for an n^3-cell cubic domain."""
    h = L / n
    if layout == DataLayout.NODE:
        x = np.arange(n + 1) * h
    else:
        x = (np.arange(n) + 0.5) * h
    return np.meshgrid(x, x, x, indexing="ij")


# --- case A: even-even x, odd-even y, periodic z (Appendix B-A) -----------

def case_a(n, layout):
    x, y, z = grids(n, layout)
    kx, ky, kz = np.pi / L, 2.5 * np.pi / L, 8 * np.pi / L
    sol = np.cos(kx * x) * np.sin(ky * y) * np.sin(kz * z)
    rhs = -(kx**2 + ky**2 + kz**2) * sol
    return rhs, sol


# --- case B: fully unbounded (Appendix B-B) --------------------------------

def _bump(s):
    """exp(10(1 - 1/(1-s^2))) with compact support |s|<1."""
    inside = np.abs(s) < 0.99999
    ss = np.where(inside, s, 0.0)
    val = np.exp(10.0 * (1.0 - 1.0 / (1.0 - ss * ss)))
    return np.where(inside, val, 0.0)


def _bump_d2(s):
    """second derivative of _bump wrt s (analytical)."""
    inside = np.abs(s) < 0.99999
    ss = np.where(inside, s, 0.0)
    one = 1.0 - ss * ss
    f = np.exp(10.0 * (1.0 - 1.0 / one))
    # f' = f * (-20 s / one^2)
    # f'' = f * [ (20 s / one^2)^2 - 20 (1 + 3 s^2) / one^3 ]
    d2 = f * ((20.0 * ss / one**2) ** 2 - 20.0 * (1.0 + 3.0 * ss * ss) / one**3)
    return np.where(inside, d2, 0.0)


def case_b(n, layout):
    x, y, z = grids(n, layout)
    sx, sy, sz = 2 * x / L - 1, 2 * y / L - 1, 2 * z / L - 1
    fx, fy, fz = _bump(sx), _bump(sy), _bump(sz)
    d2x, d2y, d2z = (_bump_d2(sx) * (2 / L) ** 2,
                     _bump_d2(sy) * (2 / L) ** 2,
                     _bump_d2(sz) * (2 / L) ** 2)
    sol = fx * fy * fz
    rhs = d2x * fy * fz + fx * d2y * fz + fx * fy * d2z
    return rhs, sol


# --- case C: semi-unbounded x (even right), semi z (odd left), unbounded y -

def case_c(n, layout):
    x, y, z = grids(n, layout)

    def g(s):
        return _bump(s)

    def g2(s, scale):
        return _bump_d2(s) * scale**2

    # X: even image around x = L -> bumps at 0.7L and 1.3L (width 0.5L)
    ax1, ax2 = (2 * x - 1.4 * L) / L, (2 * x - 2.6 * L) / L
    X = g(ax1) + g(ax2)
    X2 = g2(ax1, 2 / L) + g2(ax2, 2 / L)
    # Y: unbounded bump centered 0.5L
    ay = 2 * y / L - 1
    Y = g(ay)
    Y2 = g2(ay, 2 / L)
    # Z: odd image around z = 0 -> + at 0.3L, - at -0.3L
    az1, az2 = (2 * z - 0.6 * L) / L, (2 * z + 0.6 * L) / L
    Z = g(az1) - g(az2)
    Z2 = g2(az1, 2 / L) - g2(az2, 2 / L)

    sol = X * Y * Z
    rhs = X2 * Y * Z + X * Y2 * Z + X * Y * Z2
    return rhs, sol


CASES = {
    "A": (case_a, ((E, E), (O, E), (P, P))),
    "B": (case_b, ((U, U), (U, U), (U, U))),
    "C": (case_c, ((U, E), (U, U), (O, U))),
}


def linf_error(case, bcs, n, layout, green, eps_factor=2.0):
    fn, _ = CASES[case] if isinstance(case, str) else (case, None)
    rhs, sol = fn(n, layout)
    s = PoissonSolver((n, n, n), L, bcs, layout=layout, green_kind=green,
                      eps_factor=eps_factor)
    u = np.asarray(s.solve(rhs.astype(np.float64)))
    return np.max(np.abs(u - sol))


def observed_order(case, bcs, layout, green, ns=(32, 64), **kw):
    errs = [linf_error(case, bcs, n, layout, green, **kw) for n in ns]
    return np.log(errs[0] / errs[-1]) / np.log(ns[-1] / ns[0]), errs


# ---------------------------------------------------------------------------
# case A: spectral BCs -> CHAT2 is exact, LGF2/HEJ2 are 2nd order
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", [DataLayout.NODE, DataLayout.CELL])
def test_case_a_chat2_exact(layout):
    fn, bcs = CASES["A"]
    err = linf_error("A", bcs, 48, layout, GreenKind.CHAT2)
    assert err < 1e-10, err


@pytest.mark.parametrize("green,order", [
    (GreenKind.LGF2, 2.0), (GreenKind.HEJ2, 2.0), (GreenKind.HEJ4, 4.0),
    (GreenKind.HEJ6, 6.0),
])
def test_case_a_orders(green, order):
    # the 8 pi / L mode of the paper's case A needs n >= 64 to reach the
    # asymptotic regime of the regularized kernels (eps = 2h)
    fn, bcs = CASES["A"]
    ns = (32, 64) if green == GreenKind.LGF2 else (64, 128)
    p, errs = observed_order("A", bcs, DataLayout.NODE, green, ns=ns)
    assert p > order - 0.45, (p, errs)


# ---------------------------------------------------------------------------
# case B: fully unbounded
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", [DataLayout.NODE, DataLayout.CELL])
def test_case_b_chat2_second_order(layout):
    fn, bcs = CASES["B"]
    p, errs = observed_order("B", bcs, layout, GreenKind.CHAT2)
    assert p > 1.55, (p, errs)


@pytest.mark.parametrize("green,order", [
    (GreenKind.LGF2, 2.0), (GreenKind.HEJ2, 2.0),
    (GreenKind.HEJ4, 4.0), (GreenKind.HEJ6, 6.0),
])
def test_case_b_orders(green, order):
    fn, bcs = CASES["B"]
    ns = (32, 64) if order <= 2 else (48, 96)  # HEJ4+ preasymptotic below 48
    p, errs = observed_order("B", bcs, DataLayout.NODE, green, ns=ns)
    assert p > order - 0.5, (p, errs)


def test_case_b_hej0_spectral_like():
    """HEJ0 (truncated spectral kernel) converges faster than order 6."""
    fn, bcs = CASES["B"]
    p, errs = observed_order("B", bcs, DataLayout.NODE, GreenKind.HEJ0)
    assert p > 6.0 or errs[-1] < 1e-10, (p, errs)


# ---------------------------------------------------------------------------
# case C: semi-unbounded
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", [DataLayout.NODE, DataLayout.CELL])
def test_case_c_chat2_second_order(layout):
    fn, bcs = CASES["C"]
    p, errs = observed_order("C", bcs, layout, GreenKind.CHAT2)
    assert p > 1.55, (p, errs)


@pytest.mark.parametrize("green,order", [
    (GreenKind.HEJ2, 2.0), (GreenKind.HEJ4, 4.0),
])
def test_case_c_orders(green, order):
    fn, bcs = CASES["C"]
    ns = (32, 64) if order <= 2 else (48, 96)
    p, errs = observed_order("C", bcs, DataLayout.NODE, green, ns=ns)
    assert p > order - 0.5, (p, errs)
