"""Pruned (deferred-doubling) execution == dense (up-front) execution.

The valid-extent execution model's correctness net:

* property-based pruned-vs-dense solve equality over per-direction BC
  mixes (unb / semi / per / sym), CELL + NODE layouts, both engines,
  batched and unbatched -- BIT-EXACT on the xla engine (the pruned path
  feeds the very same FFT lengths the dense plan does; only the geometry
  around them moves), allclose on pallas (whose pruned kernels use the
  skip-zero first stage / parity-split algorithms);
* the pruned Pallas kernel entry points against numpy oracles;
* plan bookkeeping: ``valid_in`` extents, pre_padded placement, and the
  periodic no-op guarantee;
* the distributed solver under both modes + the lowered-HLO byte counts:
  a pruned plan's first forward topology switch must ship FEWER bytes
  than the dense plan's (asserted via ``hlo_stats.comm_bytes_stats``).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.bc import BCType, DataLayout
from repro.core.solver import PoissonSolver, make_plan

U, P, E, O = BCType.UNB, BCType.PER, BCType.EVEN, BCType.ODD

# per-direction BC category -> a representative (left, right) pair
CATS = {
    "unb": (U, U),
    "semi": (U, E),
    "per": (P, P),
    "sym": (E, O),
}


def _solvers(cats, layout, engine, n=4):
    bcs = tuple(CATS[c] for c in cats)
    a = PoissonSolver((n,) * 3, 1.0, bcs, layout=layout, engine=engine,
                      doubling="deferred")
    b = PoissonSolver((n,) * 3, 1.0, bcs, layout=layout, engine=engine,
                      doubling="upfront")
    return a, b


@settings(max_examples=12, deadline=None)
@given(c0=st.sampled_from(["unb", "semi", "per"]),
       c1=st.sampled_from(["unb", "semi", "per"]),
       c2=st.sampled_from(["unb", "semi", "per"]),
       layout=st.sampled_from(["CELL", "NODE"]),
       batched=st.booleans(), seed=st.integers(min_value=0, max_value=2**31))
def test_pruned_equals_dense_xla_bitexact(c0, c1, c2, layout, batched, seed):
    """Any unb/semi/per mix, any layout, batched or not: deferred ==
    upfront solve, bit for bit, on the xla engine -- the pruned path feeds
    the SAME FFT lengths the same values, only the geometry around them
    moves."""
    a, b = _solvers((c0, c1, c2), DataLayout[layout], "xla")
    rng = np.random.default_rng(seed)
    shape = ((2,) + a.input_shape) if batched else a.input_shape
    f = jnp.asarray(rng.standard_normal(shape))
    ua = np.asarray(a.solve(f))
    ub = np.asarray(b.solve(f))
    assert np.array_equal(ua, ub), np.max(np.abs(ua - ub))


@settings(max_examples=6, deadline=None)
@given(c0=st.sampled_from(list(CATS)), c1=st.sampled_from(list(CATS)),
       layout=st.sampled_from(["CELL", "NODE"]),
       seed=st.integers(min_value=0, max_value=2**31))
def test_pruned_equals_dense_xla_with_sym_dirs(c0, c1, layout, seed):
    """Mixes including symmetric (r2r) directions: equality to a few ulp.
    Sym dims are untouched by doubling, but their type-IV kinds run complex
    multiply chains whose FMA contraction XLA may fuse differently for the
    two batch shapes -- bit-exactness is only guaranteed for the
    unb/semi/per mixes above."""
    a, b = _solvers((c0, c1, "sym"), DataLayout[layout], "xla")
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.standard_normal(a.input_shape))
    ua = np.asarray(a.solve(f))
    ub = np.asarray(b.solve(f))
    np.testing.assert_allclose(ua, ub, rtol=1e-13, atol=1e-15)


@settings(max_examples=4, deadline=None)
@given(c0=st.sampled_from(["unb", "per"]), c1=st.sampled_from(["unb", "semi"]),
       layout=st.sampled_from(["CELL", "NODE"]),
       seed=st.integers(min_value=0, max_value=2**31))
def test_pruned_equals_dense_pallas(c0, c1, layout, seed):
    """The pallas engine's pruned kernels (skip-zero first stage, parity
    split) agree with the dense path to roundoff."""
    a, b = _solvers((c0, c1, "unb"), DataLayout[layout], "pallas")
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.standard_normal(a.input_shape))
    ua = np.asarray(a.solve(f))
    ub = np.asarray(b.solve(f))
    np.testing.assert_allclose(ua, ub, rtol=1e-10, atol=1e-12)


def test_pruned_engines_agree():
    """xla and pallas engines agree on a pruned all-unbounded solve (the
    pruned Stockham entry points against jnp.fft)."""
    bcs = (CATS["unb"],) * 3
    sx = PoissonSolver((8,) * 3, 1.0, bcs, engine="xla")
    sp = PoissonSolver((8,) * 3, 1.0, bcs, engine="pallas")
    f = jnp.asarray(np.random.default_rng(3).standard_normal(sx.input_shape))
    np.testing.assert_allclose(np.asarray(sx.solve(f)),
                               np.asarray(sp.solve(f)),
                               rtol=1e-9, atol=1e-11)


# -- pruned kernel entry points (numpy oracles) -----------------------------

def test_rfft_pallas_pruned_matches_padded():
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    x = rng.standard_normal((5, 16))
    got = np.asarray(ops.rfft_pallas(jnp.asarray(x), pad_to=32))
    want = np.fft.rfft(np.concatenate([x, 0 * x], axis=-1), axis=-1)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_fft1d_pruned_matches_padded():
    from repro.kernels import ops
    rng = np.random.default_rng(1)
    z = rng.standard_normal((4, 16)) + 1j * rng.standard_normal((4, 16))
    got = np.asarray(ops.fft1d(jnp.asarray(z), pad_to=32))
    want = np.fft.fft(np.concatenate([z, 0 * z], axis=-1), axis=-1)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_ifft_pruned_matches_cropped_inverse():
    from repro.kernels import ops
    rng = np.random.default_rng(2)
    Y = rng.standard_normal((3, 32)) + 1j * rng.standard_normal((3, 32))
    got = np.asarray(ops.ifft_pruned(jnp.asarray(Y), 12))
    want = np.fft.ifft(Y, axis=-1)[:, :12]
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_irfft_pruned_matches_cropped_irfft():
    from repro.kernels import ops
    rng = np.random.default_rng(3)
    Yh = np.fft.rfft(rng.standard_normal((3, 32)), axis=-1)
    got = np.asarray(ops.irfft_pruned(jnp.asarray(Yh), 32, 16))
    want = np.fft.irfft(Yh, n=32, axis=-1)[:, :16]
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_stockham_zero_tail_stage():
    from repro.kernels.fft_stockham import fft_stockham
    rng = np.random.default_rng(4)
    re = rng.standard_normal((3, 16)).astype(np.float64)
    im = rng.standard_normal((3, 16)).astype(np.float64)
    gr, gi = fft_stockham(jnp.asarray(re), jnp.asarray(im), pad_to=32)
    z = np.concatenate([re + 1j * im, np.zeros((3, 16))], axis=-1)
    want = np.fft.fft(z, axis=-1)
    np.testing.assert_allclose(np.asarray(gr) + 1j * np.asarray(gi), want,
                               rtol=1e-10, atol=1e-10)


# -- plan bookkeeping -------------------------------------------------------

def test_plan_valid_extents():
    bcs = (CATS["unb"], CATS["per"], CATS["semi"])
    dp = make_plan((8, 8, 8), 1.0, bcs)
    du = make_plan((8, 8, 8), 1.0, bcs, doubling="upfront")
    # deferred: every axis lives at its user extent outside its transform
    assert [p.valid_in for p in dp.dirs] == [8, 8, 8]
    assert not any(p.pre_padded for p in dp.dirs)
    # upfront: only the fully-unbounded dir doubles (semi keeps its r2r
    # slicing, per never pads)
    assert [p.pre_padded for p in du.dirs] == [True, False, False]
    assert [p.valid_in for p in du.dirs] == [16, 8, 8]
    # spectral storage identical across modes (Green's function reuse)
    assert [p.n_out for p in dp.dirs] == [p.n_out for p in du.dirs]


def test_periodic_plan_doubling_is_noop():
    bcs = (CATS["per"],) * 3
    dp = make_plan((8, 8, 8), 1.0, bcs)
    du = make_plan((8, 8, 8), 1.0, bcs, doubling="upfront")
    assert dp.dirs == du.dirs


def test_make_plan_rejects_unknown_doubling():
    with pytest.raises(AssertionError):
        make_plan((8, 8, 8), 1.0, (CATS["unb"],) * 3, doubling="sideways")


# -- distributed equality + the comm-bytes acceptance probe -----------------

_DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core.bc import BCType
from repro.core.comm import CommConfig
from repro.core.solver import PoissonSolver
from repro.distributed.pencil import DistributedPoissonSolver
from repro.launch.hlo_stats import comm_bytes_stats

U, P = (BCType.UNB, BCType.UNB), (BCType.PER, BCType.PER)
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)

stats = {}
for case, bcs in (("unb", (U, U, U)), ("per", (P, P, P))):
    ref = PoissonSolver((16,) * 3, 1.0, bcs)
    f = rng.standard_normal(ref.input_shape)
    want = np.asarray(ref.solve(jnp.asarray(f)))
    got = {}
    for doubling in ("deferred", "upfront"):
        ds = DistributedPoissonSolver(
            (16,) * 3, 1.0, bcs, mesh=mesh, dtype=jnp.float64,
            comm=CommConfig("overlap", 2), doubling=doubling)
        u = np.asarray(ds.solve(f))
        assert np.max(np.abs(u - want)) < 1e-10, (case, doubling)
        got[doubling] = u
        ds2 = DistributedPoissonSolver(
            (16,) * 3, 1.0, bcs, mesh=mesh, lazy_green=True,
            doubling=doubling)
        stats[(case, doubling)] = comm_bytes_stats(ds2.lower().as_text())
    # pruned == dense bit-exact through the distributed pipeline too
    assert np.array_equal(got["deferred"], got["upfront"]), case

unb_p, unb_d = stats[("unb", "deferred")], stats[("unb", "upfront")]
per_p, per_d = stats[("per", "deferred")], stats[("per", "upfront")]
# 4 switches per solve in every lowering
assert len(unb_p["per_collective"]) == 4, unb_p
# the acceptance criterion: the pruned plan's FIRST forward switch moves
# less data than the dense plan's (it ships n-point axes, never 2n)
assert unb_p["first_bytes"] < unb_d["first_bytes"], (unb_p, unb_d)
assert unb_p["first_bytes"] * 2 <= unb_d["first_bytes"], (unb_p, unb_d)
assert unb_p["total_bytes"] < unb_d["total_bytes"]
# periodic: doubling is a plan no-op, wire bytes identical
assert per_p["per_collective"] == per_d["per_collective"], (per_p, per_d)
print("OK " + json.dumps({"pruned_first": unb_p["first_bytes"],
                          "dense_first": unb_d["first_bytes"]}))
"""


def test_distributed_pruned_vs_dense_and_comm_bytes():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_COMM_CACHE", None)
    out = subprocess.run(
        [sys.executable, "-c", _DIST_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
