"""Ring attention (seq-sharded KV rotation) == naive attention.

Subprocess with 8 host devices; covers causal, sliding-window and
prefix-LM masks, GQA head grouping, and a head count (6) that does NOT
divide the ring size (the starcoder2 situation).
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_smoke
from repro.models import attention as attn
from repro.models import transformer as tf

mesh = jax.make_mesh((2, 4), ("data", "model"))

for arch, kw in (("qwen3-0.6b", {}),                      # causal + qk_norm
                 ("starcoder2-7b", {}),                   # window
                 ("paligemma-3b", {"prefix_len": 12})):   # prefix-LM
    cfg = get_smoke(arch)
    cfg = dataclasses.replace(cfg, n_heads=6, n_kv=2)      # 6 % 4 != 0
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda a: a[0], params["layers"])["attn"]
    rng = np.random.default_rng(0)
    b, s = 2, 32
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    want = attn.attention(p, cfg, x, pos, causal=True, **kw)
    got = jax.jit(lambda xx: attn.attention_ring(
        p, cfg, xx, mesh, causal=True, **kw))(x)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 5e-2, (arch, err)
print("OK")
"""


@pytest.mark.slow
def test_ring_attention_matches_naive():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
