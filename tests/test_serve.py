"""Serving correctness net (DESIGN.md #11).

The server is a concurrency layer over an already-validated solve, so
every test here reduces to one invariant: serving must change WHEN and
HOW solves run, never WHAT they compute.

* coalesced batched solve bit-exact (xla) vs the same requests solved
  individually, across mixed tenants and padded batch ranks;
* the latency deadline flushes a partial batch (a lone request is never
  held hostage waiting for co-batchable traffic);
* requests with different plan keys never coalesce, and each key's
  responses match its own plan's solve (mixed-key isolation);
* the warm pool evicts LRU plans under memory-budget pressure -- also
  from the module solver LRU -- and an evicted key transparently
  rebuilds;
* a fault-injected request degrades through the PR-6 ladder without
  poisoning co-batched tenants: every co-batched response stays
  bit-exact and the degradation records surface per tenant;
* admission: backpressure rejections, bad-shape rejections, and
  submit-after-stop.
"""
import threading

import numpy as np
import pytest

from repro.core.bc import BCType, DataLayout
from repro.core.solver import clear_solver_cache, get_solver, \
    solver_cache_info
from repro.runtime import faults
from repro.serve import (AdmissionError, PlanSpec, PoissonServer,
                         ServerClosed, default_batch_ranks, percentile)

E, O, P, U = BCType.EVEN, BCType.ODD, BCType.PER, BCType.UNB
N = 8
UNB3 = ((U, U),) * 3
PER3 = ((P, P),) * 3


def _spec(bcs=UNB3, **kw):
    return PlanSpec(shape=(N, N, N), bcs=bcs, **kw)


def _rhs(b, seed=0, grid=(N, N, N)):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(grid) for _ in range(b)]


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_solver_cache()
    yield
    clear_solver_cache()


# -- coalescing correctness --------------------------------------------------

def test_coalesced_batch_bitexact_vs_individual():
    spec = _spec()
    fs = _rhs(7, seed=1)                    # 7 -> one full 4-batch + 3->4 pad
    with PoissonServer(max_batch=4, max_delay_ms=2) as srv:
        futs = [srv.submit(f, spec, tenant=f"t{i % 3}")
                for i, f in enumerate(fs)]
        res = [f.result(timeout=120) for f in futs]
    assert any(r.batch_size > 1 for r in res), "nothing coalesced"
    s = get_solver((N, N, N), 1.0, UNB3)
    for f, r in zip(fs, res):
        want = np.asarray(s.solve(f))
        # same plan, same xla pipeline, batch rows are independent: the
        # served (coalesced, possibly zero-padded) answer is BIT-exact
        np.testing.assert_array_equal(want, r.u)


def test_padding_to_nearest_rank():
    spec = _spec(bcs=PER3)
    with PoissonServer(max_batch=8, max_delay_ms=1) as srv:
        futs = [srv.submit(f, spec) for f in _rhs(3, seed=2)]
        res = [f.result(timeout=120) for f in futs]
    ranks = default_batch_ranks(8)
    for r in res:
        assert r.padded_to in ranks
        assert r.padded_to >= r.batch_size
    # 3 live rhs either ran as one deadline batch padded 3->4, or split
    batch = [r for r in res if r.batch_size == 3]
    if batch:
        assert batch[0].padded_to == 4


def test_deadline_flush_releases_partial_batch():
    spec = _spec(bcs=PER3)
    with PoissonServer(max_batch=64, max_delay_ms=5) as srv:
        [f] = _rhs(1, seed=3)
        fut = srv.submit(f, spec)
        r = fut.result(timeout=120)         # far below max_batch: only the
        assert r.batch_size == 1            # deadline can have flushed it
        assert srv.server_stats()["deadline_flushes"] >= 1


def test_mixed_plan_keys_never_coalesce():
    spec_a = _spec(bcs=UNB3)
    spec_b = _spec(bcs=PER3)
    spec_c = _spec(bcs=((E, E), (O, E), (P, P)), layout=DataLayout.NODE)
    grids = {spec_a.key(): (N, N, N), spec_b.key(): (N, N, N),
             spec_c.key(): (N + 1, N + 1, N + 1)}
    with PoissonServer(max_batch=8, max_delay_ms=10) as srv:
        futs = []
        for i, spec in enumerate([spec_a, spec_b, spec_c] * 3):
            [f] = _rhs(1, seed=10 + i, grid=grids[spec.key()])
            futs.append((spec, f, srv.submit(f, spec, tenant=f"t{i % 2}")))
        res = [(spec, f, fut.result(timeout=240)) for spec, f, fut in futs]
    for spec, f, r in res:
        want = np.asarray(spec.build().solve(f))
        np.testing.assert_array_equal(want, r.u)   # no cross-plan bleed
        assert r.batch_size <= 3                   # only same-key coalesce


# -- warm pool ---------------------------------------------------------------

def test_warm_pool_evicts_under_memory_pressure():
    # three plan keys, budget sized to hold roughly one: serving all three
    # must evict (pool LRU + module LRU) yet keep answering correctly
    specs = [_spec(bcs=UNB3), _spec(bcs=PER3),
             _spec(bcs=((E, E), (O, O), (E, E)))]
    one_plan_mb = 0.02                      # 8^3 f64 green ~4KB; tiny budget
    with PoissonServer(max_batch=2, max_delay_ms=1,
                       memory_budget_mb=one_plan_mb) as srv:
        for rep in range(2):
            for i, spec in enumerate(specs):
                [f] = _rhs(1, seed=20 + i)
                r = srv.solve(f, spec, timeout=240)
                want = np.asarray(spec.build().solve(f))
                np.testing.assert_array_equal(want, r.u)
        info = srv.server_stats()["pool"]
    assert info["evictions"] >= 1
    assert info["budget_bytes"] == int(one_plan_mb * 1e6)
    # eviction reached through to the module LRU too
    assert solver_cache_info()["evictions"] >= 1


def test_warm_pool_unbounded_keeps_plans_resident():
    specs = [_spec(bcs=UNB3), _spec(bcs=PER3)]
    with PoissonServer(max_batch=2, max_delay_ms=1) as srv:
        for spec in specs * 2:
            [f] = _rhs(1, seed=31)
            srv.solve(f, spec, timeout=240)
        info = srv.server_stats()["pool"]
    assert info["evictions"] == 0
    assert info["size"] == 2
    assert info["hits"] >= 2                # second round hit warm plans


# -- resilience --------------------------------------------------------------

def test_faulted_request_degrades_without_poisoning_cobatched():
    """One tenant's request arms a hard fault at solve dispatch; the PR-6
    ladder steps relayout scheduled->baseline (bit-exact on xla), the
    whole co-batched solve still returns the right answer for EVERY
    tenant, and only that batch carries degradation records."""
    spec = _spec()
    fs = _rhs(4, seed=4)
    plan = faults.FaultPlan([{"kind": "error", "stage": "solve.dispatch",
                              "count": 1}])
    with PoissonServer(max_batch=4, max_delay_ms=50) as srv:
        futs = [srv.submit(f, spec, tenant=f"t{i}",
                           fault_plan=plan if i == 2 else None)
                for i, f in enumerate(fs)]
        res = [f.result(timeout=240) for f in futs]
        tstats = srv.tenant_stats()
    assert [r.batch_size for r in res] == [4, 4, 4, 4]
    assert plan.log, "armed fault never fired"
    # the ladder downgraded exactly once and every tenant saw the record
    for r in res:
        assert len(r.degradations) == 1
        assert r.degradations[0]["action"] == "relayout:scheduled->baseline"
    for i in range(4):
        assert len(tstats[f"t{i}"]["degradations"]) == 1
    # ...and nobody's answer was poisoned: baseline relayout is bit-exact
    s = get_solver((N, N, N), 1.0, UNB3)
    for f, r in zip(fs, res):
        np.testing.assert_array_equal(np.asarray(s.solve(f)), r.u)


def test_faulted_request_does_not_degrade_clean_warm_plan():
    """The armed batch runs on a fault-token shadow solver: the clean warm
    plan keeps its scheduled relayout for later traffic."""
    spec = _spec(bcs=PER3)
    plan = faults.FaultPlan([{"kind": "error", "stage": "solve.dispatch",
                              "count": 1}])
    with PoissonServer(max_batch=1, max_delay_ms=1) as srv:
        [f0] = _rhs(1, seed=5)
        r_clean0 = srv.solve(f0, spec, timeout=240)
        r_faulted = srv.submit(f0, spec, fault_plan=plan).result(timeout=240)
        r_clean1 = srv.solve(f0, spec, timeout=240)
    assert r_faulted.degradations and not r_clean0.degradations \
        and not r_clean1.degradations
    np.testing.assert_array_equal(r_clean0.u, r_faulted.u)
    np.testing.assert_array_equal(r_clean0.u, r_clean1.u)


# -- admission + lifecycle ---------------------------------------------------

def test_admission_rejects_bad_shape_and_counts_it():
    spec = _spec()
    with PoissonServer(max_batch=2, max_delay_ms=1) as srv:
        with pytest.raises(AdmissionError, match="does not match"):
            srv.submit(np.zeros((N, N)), spec, tenant="short")
        tstats = srv.tenant_stats()
    assert tstats["short"]["rejected"] == 1
    assert srv.server_stats()["rejected"] == 1


def test_submit_after_stop_raises_server_closed():
    spec = _spec(bcs=PER3)
    srv = PoissonServer(max_batch=2, max_delay_ms=1).start()
    [f] = _rhs(1, seed=6)
    srv.solve(f, spec, timeout=240)
    srv.stop()
    with pytest.raises(ServerClosed):
        srv.submit(f, spec)


def test_backpressure_rejects_beyond_max_pending():
    spec = _spec(bcs=PER3)
    srv = PoissonServer(max_batch=4, max_delay_ms=10_000, max_pending=3)
    srv.start()
    try:
        fs = _rhs(5, seed=7)
        futs = [srv.submit(f, spec) for f in fs[:3]]
        with pytest.raises(AdmissionError, match="backpressure"):
            srv.submit(fs[3], spec)
    finally:
        srv.stop()                          # drain flushes the 3 pending
    assert all(f.result(timeout=240).batch_size == 3 for f in futs)


def test_stop_drain_serves_everything():
    spec = _spec(bcs=PER3)
    srv = PoissonServer(max_batch=8, max_delay_ms=10_000).start()
    futs = [srv.submit(f, spec) for f in _rhs(3, seed=8)]
    srv.stop(drain=True)                    # deadline far away: drain flush
    assert all(f.result(timeout=1).u.shape == (N, N, N) for f in futs)
    assert srv.server_stats()["completed"] == 3


def test_drain_deadline_fails_wedged_requests():
    """One wedged solve (a stalled collective, modelled by a ``stall``
    fault sleeping 60s inside dispatch) must not hang ``stop(drain=True)``
    forever: the deadline expires, every unserved request fails with a
    position-stamped ``ServerClosed``, the wedged worker thread is
    abandoned, and shutdown returns in bounded time."""
    import time
    spec = _spec(bcs=PER3)
    plan = faults.FaultPlan([{"kind": "stall", "stage": "solve.dispatch",
                              "seconds": 60.0}])
    srv = PoissonServer(max_batch=1, max_delay_ms=1).start()
    fs = _rhs(3, seed=9)
    wedged = srv.submit(fs[0], spec, fault_plan=plan)
    # let the wedged batch reach the worker so the deadline is the only
    # way out, then pile clean requests behind it (workers=1)
    deadline = time.monotonic() + 10
    while srv.server_stats()["batches"] < 1 and time.monotonic() < deadline:
        time.sleep(0.01)
    stuck = [srv.submit(f, spec) for f in fs[1:]]
    t0 = time.monotonic()
    srv.stop(drain=True, timeout=1.0)
    assert time.monotonic() - t0 < 30, "drain deadline did not bound stop"
    positions = []
    for f in [wedged] + stuck:
        with pytest.raises(ServerClosed) as ei:
            f.result(timeout=1)
        assert "drain deadline" in str(ei.value)
        positions.append(ei.value.queue_position)
    # every victim got a distinct 1-based queue position, in-flight first
    assert sorted(positions) == [1, 2, 3], positions
    assert positions[0] == 1, "wedged in-flight request must rank first"
    st = srv.server_stats()
    assert st["drain_timeouts"] == 3
    assert st["failed"] >= 3 and st.get("abandoned_threads", 0) >= 1
    # a stopped server still refuses new work cleanly
    with pytest.raises(ServerClosed):
        srv.submit(fs[0], spec)


# -- stats -------------------------------------------------------------------

def test_tenant_stats_percentiles_and_occupancy():
    spec = _spec(bcs=PER3)
    with PoissonServer(max_batch=2, max_delay_ms=2) as srv:
        futs = [srv.submit(f, spec, tenant="solo") for f in _rhs(6, seed=9)]
        [f.result(timeout=240) for f in futs]
        t = srv.tenant_stats()["solo"]
    assert t["served"] == 6
    assert t["p50_ms"] <= t["p95_ms"] <= t["p99_ms"]
    assert 1 <= t["mean_batch_occupancy"] <= 2


def test_percentile_nearest_rank():
    xs = list(range(1, 101))
    assert percentile(xs, 50) == 50
    assert percentile(xs, 95) == 95
    assert percentile(xs, 99) == 99
    assert percentile([7.0], 99) == 7.0


# -- threaded multi-tenant soak (the acceptance harness in miniature) --------

def test_threaded_tenants_mixed_keys_all_bitexact():
    specs = [_spec(bcs=UNB3), _spec(bcs=PER3)]
    n_tenants, per_tenant = 8, 3
    results = {}
    errors = []

    def tenant(i):
        try:
            rng = np.random.default_rng(100 + i)
            spec = specs[i % 2]
            out = []
            for k in range(per_tenant):
                f = rng.standard_normal((N, N, N))
                r = srv.solve(f, spec, tenant=f"t{i}", timeout=240)
                out.append((f, r))
            results[i] = out
        except Exception as e:  # noqa: BLE001 -- collected for the assert
            errors.append((i, e))

    with PoissonServer(max_batch=4, max_delay_ms=5) as srv:
        threads = [threading.Thread(target=tenant, args=(i,))
                   for i in range(n_tenants)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = srv.server_stats()
    assert not errors, errors
    assert stats["completed"] == n_tenants * per_tenant
    refs = {spec.key(): spec.build() for spec in specs}
    for i, out in results.items():
        s = refs[specs[i % 2].key()]
        for f, r in out:
            np.testing.assert_array_equal(np.asarray(s.solve(f)), r.u)
