"""End-to-end system tests: launchers, fault tolerance, examples."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, env_extra=None, timeout=420):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    env.update(env_extra or {})
    return subprocess.run([sys.executable] + args, capture_output=True,
                          text=True, env=env, cwd=ROOT, timeout=timeout)


def test_train_launcher_failure_and_resume(tmp_path):
    """Simulated crash at step 12 -> relaunch resumes from checkpoint 10."""
    ck = str(tmp_path / "ck")
    out = _run(["-m", "repro.launch.train", "--arch", "qwen3-0.6b",
                "--smoke", "--steps", "20", "--batch", "2", "--seq", "32",
                "--ckpt-dir", ck, "--ckpt-every", "5", "--fail-at", "12"])
    assert out.returncode != 0
    assert "simulated failure" in out.stdout + out.stderr
    out2 = _run(["-m", "repro.launch.train", "--arch", "qwen3-0.6b",
                 "--smoke", "--steps", "20", "--batch", "2", "--seq", "32",
                 "--ckpt-dir", ck, "--ckpt-every", "5"])
    assert out2.returncode == 0, out2.stderr[-1500:]
    assert "resumed from step 10" in out2.stdout
    assert "[train] done" in out2.stdout


def test_solve_launcher_distributed():
    """2x2 pencil grid solve CLI reaches the analytical solution."""
    out = _run(["-m", "repro.launch.solve", "--n", "24", "--p1", "2",
                "--p2", "2", "--bcs", "unb", "--comm", "pipelined",
                "--repeats", "1"])
    assert out.returncode == 0, out.stderr[-1500:]
    assert "E_inf=" in out.stdout
    err = float(out.stdout.split("E_inf=")[1].split(",")[0])
    assert err < 5e-2


@pytest.mark.slow
def test_quickstart_example():
    out = _run(["examples/quickstart.py"])
    assert out.returncode == 0, out.stderr[-1500:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_serve_example():
    out = _run(["examples/serve_lm.py", "--batch", "2", "--prompt-len",
                "16", "--gen", "8"])
    assert out.returncode == 0, out.stderr[-1500:]
    assert "generated" in out.stdout
