"""Training substrate: optimizer math, compression, checkpoint/restart."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI images without hypothesis: deterministic local shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.ckpt import checkpoint as ck
from repro.configs import get_smoke
from repro.data.pipeline import synthetic_batch
from repro.training import optimizer as opt
from repro.training.train_step import make_train_state, train_step_fn


def test_adamw_decreases_quadratic():
    cfg = opt.AdamWConfig(lr=0.1, warmup=0, weight_decay=0.0,
                          total_steps=1000)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init_opt_state(params)
    for _ in range(200):
        grads = {"w": 2.0 * params["w"]}
        params, state, m = opt.adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip_metric():
    cfg = opt.AdamWConfig(grad_clip=1e-3)
    params = {"w": jnp.ones((4,))}
    state = opt.init_opt_state(params)
    _, _, m = opt.adamw_update(cfg, params, {"w": jnp.full((4,), 100.0)},
                               state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_int8_error_feedback_property(seed):
    """Property: compressed-grad + carried error == original grad exactly."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(64) * 10 ** rng.uniform(-4, 2))
    deq, err = opt.compress_int8(g, jnp.zeros_like(g))
    np.testing.assert_allclose(np.asarray(deq + err), np.asarray(g),
                               rtol=1e-5, atol=1e-7)
    # quantization error bounded by scale/2
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.abs(err).max()) <= scale * 0.5 + 1e-9


def test_compressed_training_converges():
    cfg = get_smoke("qwen3-0.6b")
    adam = opt.AdamWConfig(lr=1e-3, grad_compress="int8", warmup=0)
    state = make_train_state(jax.random.PRNGKey(0), cfg, adam=adam)
    step = jax.jit(train_step_fn(cfg, adam=adam))
    losses = []
    for i in range(8):
        state, m = step(state, synthetic_batch(cfg, 0, 2, 16))  # same batch
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]  # memorizes the repeated batch


def test_checkpoint_roundtrip_and_gc(tmp_path):
    cfg = get_smoke("minitron-8b")
    state = make_train_state(jax.random.PRNGKey(0), cfg)
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4, 5):
        ck.save(d, s, state, keep_last=3)
    assert ck.all_steps(d) == [3, 4, 5]
    assert ck.latest_step(d) == 5
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        state)
    restored = ck.restore(d, 5, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_determinism(tmp_path):
    """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
    cfg = get_smoke("qwen3-0.6b")
    step = jax.jit(train_step_fn(cfg))

    def run(state, a, b):
        for i in range(a, b):
            state, _ = step(state, synthetic_batch(cfg, i, 2, 16))
        return state

    s_ref = run(make_train_state(jax.random.PRNGKey(0), cfg), 0, 4)

    s = run(make_train_state(jax.random.PRNGKey(0), cfg), 0, 2)
    d = str(tmp_path / "ck")
    ck.save(d, 2, s)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), s)
    s2 = run(ck.restore(d, 2, like), 2, 4)

    for a, b in zip(jax.tree.leaves(s_ref.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
