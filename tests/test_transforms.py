"""Property-based DCT/DST I-IV coverage (scipy.fft oracle + algebraic laws).

Random lengths 3..129 (odd and even), all 8 r2r kinds, both engines, via
``hypothesis`` when installed or the deterministic ``_hypothesis_shim``:

* scipy oracle        T(x) == scipy.fft.{dct,dst}(x, type, norm=None)
* round trip          bwd(fwd(x)) == x / normfact  (fwd o bwd = n * id)
* linearity           T(a x + b y) == a T(x) + b T(y)
* Parseval energy     sum w_out y^2 == scale * sum w_in x^2, with the
                      endpoint weights of each kind's (non-orthonormal)
                      scipy convention and scale = 1 / normfact
"""
import numpy as np
import pytest
import scipy.fft as sfft
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI images without hypothesis: deterministic local shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.bc import TransformKind
from repro.core import transforms as tr
from repro.core.engine import TransformEngine

KINDS = {
    TransformKind.DCT1: ("dct", 1), TransformKind.DCT2: ("dct", 2),
    TransformKind.DCT3: ("dct", 3), TransformKind.DCT4: ("dct", 4),
    TransformKind.DST1: ("dst", 1), TransformKind.DST2: ("dst", 2),
    TransformKind.DST3: ("dst", 3), TransformKind.DST4: ("dst", 4),
}

ENGINES = {"xla": None, "pallas": TransformEngine("pallas")}


def _scipy(kind, x):
    name, t = KINDS[kind]
    fn = sfft.dct if name == "dct" else sfft.dst
    return fn(x, type=t, axis=-1, norm=None)


def _energy_weights(kind, m):
    """Input/output endpoint weights + scale of each kind's Parseval-style
    identity  sum w_out y^2 = scale * sum w_in x^2  under the unnormalized
    scipy convention (scale == 1 / r2r_normfact)."""
    name, t = KINDS[kind]
    win = np.ones(m)
    wout = np.ones(m)
    if t == 1 and name == "dct":
        win[0] = win[-1] = 0.5
        wout = win.copy()
    elif t == 2:
        if name == "dct":
            wout[0] = 0.5
        else:
            wout[-1] = 0.5
    elif t == 3:
        if name == "dct":
            win[0] = 0.5
        else:
            win[-1] = 0.5
    return win, wout, 1.0 / tr.r2r_normfact(kind, m)


SIZES = st.integers(min_value=3, max_value=129)
ALL_KINDS = st.sampled_from(list(KINDS))
ENGINE_NAMES = st.sampled_from(list(ENGINES))
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=40, deadline=None)
@given(m=SIZES, kind=ALL_KINDS, engine=ENGINE_NAMES, seed=SEEDS)
def test_r2r_matches_scipy_property(m, kind, engine, seed):
    """Oracle property: any length, any kind, either engine == scipy."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((2, m))
    got = np.asarray(tr.r2r_forward(jnp.asarray(x), kind,
                                    engine=ENGINES[engine]))
    np.testing.assert_allclose(got, _scipy(kind, x), rtol=1e-7, atol=1e-7)


@settings(max_examples=40, deadline=None)
@given(m=SIZES, kind=ALL_KINDS, engine=ENGINE_NAMES, seed=SEEDS)
def test_r2r_roundtrip_property(m, kind, engine, seed):
    """fwd o bwd = n * id: the inverse recovers x up to the normfact."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((3, m))
    eng = ENGINES[engine]
    y = tr.r2r_forward(jnp.asarray(x), kind, engine=eng)
    back = tr.r2r_backward(y, kind, engine=eng) * tr.r2r_normfact(kind, m)
    np.testing.assert_allclose(np.asarray(back), x, rtol=1e-6, atol=1e-8)


@settings(max_examples=25, deadline=None)
@given(m=SIZES, kind=ALL_KINDS, seed=SEEDS)
def test_r2r_linearity_property(m, kind, seed):
    """Property: T(a x + b y) == a T(x) + b T(y) and scipy agreement."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(m)
    y = rng.standard_normal(m)
    a, b = rng.standard_normal(2)
    xa, ya = jnp.asarray(x), jnp.asarray(y)
    lhs = np.asarray(tr.r2r_forward(a * xa + b * ya, kind))
    rhs = a * np.asarray(tr.r2r_forward(xa, kind)) + b * np.asarray(
        tr.r2r_forward(ya, kind))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-7, atol=1e-7)
    np.testing.assert_allclose(lhs, _scipy(kind, a * x + b * y),
                               rtol=1e-7, atol=1e-7)


@settings(max_examples=40, deadline=None)
@given(m=SIZES, kind=ALL_KINDS, engine=ENGINE_NAMES, seed=SEEDS)
def test_r2r_parseval_property(m, kind, engine, seed):
    """Energy is preserved up to the convention's endpoint weights."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(m)
    y = np.asarray(tr.r2r_forward(jnp.asarray(x), kind,
                                  engine=ENGINES[engine]))
    win, wout, scale = _energy_weights(kind, m)
    lhs = float(np.sum(wout * y * y))
    rhs = scale * float(np.sum(win * x * x))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(m=SIZES, kind=ALL_KINDS, seed=SEEDS)
def test_r2r_matches_legacy_full_complex(m, kind, seed):
    """Half-spectrum path == the seed full-complex path (transforms_ref)."""
    from repro.core import transforms_ref as trf
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((3, m)))
    got = np.asarray(tr.r2r_forward(x, kind))
    want = np.asarray(trf.r2r_forward(x, kind))
    np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("kind", list(KINDS))
@pytest.mark.parametrize("m", [15, 16])  # odd and even sizes
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_r2r_half_spectrum_all_kinds_dtypes(kind, m, dtype):
    """f32/f64 dtype preservation vs scipy (fixed shapes: dtype is the
    subject here, the size sweep lives in the properties above)."""
    rng = np.random.default_rng(7 * m + sum(kind.value.encode()))
    x = rng.standard_normal((4, m)).astype(dtype)
    got = np.asarray(tr.r2r_forward(jnp.asarray(x), kind))
    assert got.dtype == dtype
    tol = 1e-4 if dtype == np.float32 else 1e-9
    np.testing.assert_allclose(got, _scipy(kind, x), rtol=tol, atol=tol)
