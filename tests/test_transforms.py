"""DCT/DST I-IV vs scipy.fft oracle + inverse roundtrip properties."""
import numpy as np
import pytest
import scipy.fft as sfft
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI images without hypothesis: deterministic local shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.bc import TransformKind
from repro.core import transforms as tr

KINDS = {
    TransformKind.DCT1: ("dct", 1), TransformKind.DCT2: ("dct", 2),
    TransformKind.DCT3: ("dct", 3), TransformKind.DCT4: ("dct", 4),
    TransformKind.DST1: ("dst", 1), TransformKind.DST2: ("dst", 2),
    TransformKind.DST3: ("dst", 3), TransformKind.DST4: ("dst", 4),
}


def _scipy(kind, x):
    name, t = KINDS[kind]
    fn = sfft.dct if name == "dct" else sfft.dst
    return fn(x, type=t, axis=-1, norm=None)


@pytest.mark.parametrize("kind", list(KINDS))
@pytest.mark.parametrize("m", [3, 4, 5, 8, 16, 17, 33])
def test_r2r_matches_scipy(kind, m):
    if kind == TransformKind.DCT1 and m < 2:
        pytest.skip("DCT-I needs m >= 2")
    rng = np.random.default_rng(42 + m)
    x = rng.standard_normal((2, m)).astype(np.float64)
    got = np.asarray(tr.r2r_forward(jnp.asarray(x), kind))
    want = _scipy(kind, x)
    np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("kind", list(KINDS))
@pytest.mark.parametrize("m", [4, 9, 16])
def test_r2r_roundtrip(kind, m):
    rng = np.random.default_rng(m)
    x = rng.standard_normal((3, m))
    y = tr.r2r_forward(jnp.asarray(x), kind)
    back = tr.r2r_backward(y, kind) * tr.r2r_normfact(kind, m)
    np.testing.assert_allclose(np.asarray(back), x, rtol=1e-7, atol=1e-9)


@pytest.mark.parametrize("kind", list(KINDS))
@pytest.mark.parametrize("m", [15, 16])  # odd and even sizes
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_r2r_half_spectrum_all_kinds_dtypes(kind, m, dtype):
    """Half-spectrum path: all 8 kinds x odd/even sizes x f32/f64 vs scipy."""
    rng = np.random.default_rng(7 * m + sum(kind.value.encode()))
    x = rng.standard_normal((4, m)).astype(dtype)
    got = np.asarray(tr.r2r_forward(jnp.asarray(x), kind))
    assert got.dtype == dtype
    tol = 1e-4 if dtype == np.float32 else 1e-9
    np.testing.assert_allclose(got, _scipy(kind, x), rtol=tol, atol=tol)


@pytest.mark.parametrize("kind", list(KINDS))
@pytest.mark.parametrize("m", [5, 12])
def test_r2r_matches_legacy_full_complex(kind, m):
    """New half-spectrum path == the seed full-complex path (transforms_ref)."""
    from repro.core import transforms_ref as trf
    rng = np.random.default_rng(m)
    x = jnp.asarray(rng.standard_normal((3, m)))
    got = np.asarray(tr.r2r_forward(x, kind))
    want = np.asarray(trf.r2r_forward(x, kind))
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("kind", [TransformKind.DCT2, TransformKind.DST2])
def test_r2r_float32(kind):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 32)).astype(np.float32)
    got = np.asarray(tr.r2r_forward(jnp.asarray(x), kind))
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, _scipy(kind, x), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=3, max_value=40),
    kind=st.sampled_from(list(KINDS)),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_r2r_linearity_property(m, kind, seed):
    """Property: T(a x + b y) == a T(x) + b T(y) and scipy agreement."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(m)
    y = rng.standard_normal(m)
    a, b = rng.standard_normal(2)
    xa, ya = jnp.asarray(x), jnp.asarray(y)
    lhs = np.asarray(tr.r2r_forward(a * xa + b * ya, kind))
    rhs = a * np.asarray(tr.r2r_forward(xa, kind)) + b * np.asarray(
        tr.r2r_forward(ya, kind))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-7, atol=1e-7)
    np.testing.assert_allclose(lhs, _scipy(kind, a * x + b * y),
                               rtol=1e-7, atol=1e-7)
