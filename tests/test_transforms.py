"""DCT/DST I-IV vs scipy.fft oracle + inverse roundtrip properties."""
import numpy as np
import pytest
import scipy.fft as sfft
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.bc import TransformKind
from repro.core import transforms as tr

KINDS = {
    TransformKind.DCT1: ("dct", 1), TransformKind.DCT2: ("dct", 2),
    TransformKind.DCT3: ("dct", 3), TransformKind.DCT4: ("dct", 4),
    TransformKind.DST1: ("dst", 1), TransformKind.DST2: ("dst", 2),
    TransformKind.DST3: ("dst", 3), TransformKind.DST4: ("dst", 4),
}


def _scipy(kind, x):
    name, t = KINDS[kind]
    fn = sfft.dct if name == "dct" else sfft.dst
    return fn(x, type=t, axis=-1, norm=None)


@pytest.mark.parametrize("kind", list(KINDS))
@pytest.mark.parametrize("m", [3, 4, 5, 8, 16, 17, 33])
def test_r2r_matches_scipy(kind, m):
    if kind == TransformKind.DCT1 and m < 2:
        pytest.skip("DCT-I needs m >= 2")
    rng = np.random.default_rng(42 + m)
    x = rng.standard_normal((2, m)).astype(np.float64)
    got = np.asarray(tr.r2r_forward(jnp.asarray(x), kind))
    want = _scipy(kind, x)
    np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("kind", list(KINDS))
@pytest.mark.parametrize("m", [4, 9, 16])
def test_r2r_roundtrip(kind, m):
    rng = np.random.default_rng(m)
    x = rng.standard_normal((3, m))
    y = tr.r2r_forward(jnp.asarray(x), kind)
    back = tr.r2r_backward(y, kind) * tr.r2r_normfact(kind, m)
    np.testing.assert_allclose(np.asarray(back), x, rtol=1e-7, atol=1e-9)


@pytest.mark.parametrize("kind", [TransformKind.DCT2, TransformKind.DST2])
def test_r2r_float32(kind):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 32)).astype(np.float32)
    got = np.asarray(tr.r2r_forward(jnp.asarray(x), kind))
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, _scipy(kind, x), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=3, max_value=40),
    kind=st.sampled_from(list(KINDS)),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_r2r_linearity_property(m, kind, seed):
    """Property: T(a x + b y) == a T(x) + b T(y) and scipy agreement."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(m)
    y = rng.standard_normal(m)
    a, b = rng.standard_normal(2)
    xa, ya = jnp.asarray(x), jnp.asarray(y)
    lhs = np.asarray(tr.r2r_forward(a * xa + b * ya, kind))
    rhs = a * np.asarray(tr.r2r_forward(xa, kind)) + b * np.asarray(
        tr.r2r_forward(ya, kind))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-7, atol=1e-7)
    np.testing.assert_allclose(lhs, _scipy(kind, a * x + b * y),
                               rtol=1e-7, atol=1e-7)
