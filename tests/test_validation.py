"""Analytical validation net: the paper's convergence study on closed-form
Gaussian-blob solutions (section IV), for the three domain families:

  * fully unbounded     -- Gaussian blob; u(r) = -Q erf(r / (sqrt(2) s))
                           / (4 pi r), the classic smoothed point potential
  * semi-unbounded      -- blob + its mirror image through the bounded end
                           (+ for an EVEN end, - for an ODD end): exactly
                           the Hockney mirror the solver imposes
  * fully periodic      -- wrapped (periodized) Gaussian, compared up to
                           the pinned zero mode

Each family asserts the OBSERVED convergence order over a 3-grid
refinement (least-squares slope): approaching 2 for CHAT2 (the paper's
2nd-order spectral-truncation kernel) and the high design orders for the
regularized HEJ4/HEJ6 kernels (paper Figs 6-8), on both CELL and NODE
layouts.  Thresholds carry the repo's standard preasymptotic slack (the
paper's own figures approach the design order from below at these
resolutions); the measured slopes are recorded in EXPERIMENTS.md
section "Validation".

Heavier grids are ``slow``-marked; CI runs them in the dedicated
``validation`` job.
"""
import numpy as np
import pytest
from scipy.special import erf

from repro.core.bc import BCType, DataLayout
from repro.core.green import GreenKind
from repro.core.solver import get_solver

E, O, P, U = BCType.EVEN, BCType.ODD, BCType.PER, BCType.UNB
L = 1.0
SIGMA = L / 10.0          # blob width: 5 sigma to the nearest boundary --
                          # domain-truncation floor ~1e-8, far below every
                          # asserted error level
CENTER = (0.5 * L, 0.5 * L, 0.5 * L)


def grid1d(n, layout):
    h = L / n
    if layout == DataLayout.NODE:
        return np.arange(n + 1) * h
    return (np.arange(n) + 0.5) * h


def grids(n, layout):
    x = grid1d(n, layout)
    return np.meshgrid(x, x, x, indexing="ij")


# ---------------------------------------------------------------------------
# closed-form fields
# ---------------------------------------------------------------------------

def gauss_rhs(x, y, z, c=CENTER, s=SIGMA):
    r2 = (x - c[0]) ** 2 + (y - c[1]) ** 2 + (z - c[2]) ** 2
    return np.exp(-r2 / (2.0 * s * s))


def gauss_potential(x, y, z, c=CENTER, s=SIGMA):
    """Exact solution of lap(u) = gauss_rhs on free space.

    u(r) = -Q erf(r / (sqrt(2) s)) / (4 pi r),  Q = (2 pi)^{3/2} s^3;
    the removable r -> 0 singularity is filled with the analytic limit.
    """
    r = np.sqrt((x - c[0]) ** 2 + (y - c[1]) ** 2 + (z - c[2]) ** 2)
    q = (2.0 * np.pi) ** 1.5 * s ** 3
    near = r < 1e-12
    rs = np.where(near, 1.0, r)
    u = -q * erf(rs / (np.sqrt(2.0) * s)) / (4.0 * np.pi * rs)
    u0 = -q * 2.0 / (np.sqrt(2.0 * np.pi) * s) / (4.0 * np.pi)
    return np.where(near, u0, u)


def case_unbounded(n, layout):
    x, y, z = grids(n, layout)
    return gauss_rhs(x, y, z), gauss_potential(x, y, z)


def case_semi_even(n, layout):
    """x: (UNB, EVEN) -- bounded even end at x = L; y, z fully unbounded.

    The even symmetry mirrors the blob through x = L: the exact solution
    adds the image blob's free-space potential (center 2L - cx)."""
    x, y, z = grids(n, layout)
    rhs = gauss_rhs(x, y, z)
    cimg = (2.0 * L - CENTER[0], CENTER[1], CENTER[2])
    sol = gauss_potential(x, y, z) + gauss_potential(x, y, z, c=cimg)
    return rhs, sol


def case_semi_odd(n, layout):
    """z: (ODD, UNB) -- bounded odd end at z = 0: image enters negated."""
    x, y, z = grids(n, layout)
    rhs = gauss_rhs(x, y, z)
    cimg = (CENTER[0], CENTER[1], -CENTER[2])
    sol = gauss_potential(x, y, z) - gauss_potential(x, y, z, c=cimg)
    return rhs, sol


def _wrapped(x, c, s, deriv2=False, images=4):
    """Periodized 1-D Gaussian (or its 2nd derivative), K images each way."""
    acc = np.zeros_like(x)
    for k in range(-images, images + 1):
        d = x - c + k * L
        g = np.exp(-d * d / (2.0 * s * s))
        if deriv2:
            acc += g * (d * d / s ** 4 - 1.0 / s ** 2)
        else:
            acc += g
    return acc


def case_periodic(s):
    """Fully periodic wrapped-Gaussian product; exact up to the zero mode
    (the solver pins the mean of u to zero, so the comparison does too)."""
    def build(n, layout):
        x1 = grid1d(n, layout)
        w = [_wrapped(x1, c, s) for c in CENTER]
        w2 = [_wrapped(x1, c, s, deriv2=True) for c in CENTER]

        def outer3(a, b, c):
            return (a[:, None, None] * b[None, :, None]
                    * c[None, None, :])

        sol = outer3(w[0], w[1], w[2])
        rhs = (outer3(w2[0], w[1], w[2]) + outer3(w[0], w2[1], w[2])
               + outer3(w[0], w[1], w2[2]))
        mean = (np.sqrt(2.0 * np.pi) * s / L) ** 3   # analytic domain mean
        return rhs, sol - mean
    return build


CASES = {
    "unb": (case_unbounded, ((U, U), (U, U), (U, U))),
    "semi-even": (case_semi_even, ((U, E), (U, U), (U, U))),
    "semi-odd": (case_semi_odd, ((U, U), (U, U), (O, U))),
    # narrow blob: CHAT2's error is pure rhs-sampling aliasing here
    "per": (case_periodic(L / 8.0), ((P, P), (P, P), (P, P))),
    # wide blob: puts the regularized HEJ kernels in their asymptotic range
    # on cheap periodic grids (no domain doubling)
    "per-wide": (case_periodic(L / 4.0), ((P, P), (P, P), (P, P))),
}


def linf_error(case, n, layout, green):
    fn, bcs = CASES[case]
    rhs, sol = fn(n, layout)
    s = get_solver((n, n, n), L, bcs, layout=layout, green_kind=green)
    u = np.asarray(s.solve(rhs.astype(np.float64)))
    return float(np.max(np.abs(u - sol)))


def observed_order(case, layout, green, ns):
    """Least-squares slope of log(err) against log(n) over the 3 grids."""
    errs = [linf_error(case, n, layout, green) for n in ns]
    p = -np.polyfit(np.log(ns), np.log(errs), 1)[0]
    return p, errs


LAYOUTS = [DataLayout.NODE, DataLayout.CELL]


# ---------------------------------------------------------------------------
# fully unbounded (paper Fig 6)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", LAYOUTS)
def test_unbounded_chat2_order2(layout):
    # measured: 1.86 (NODE) / 1.72 (CELL), approaching 2 from below --
    # the repo-standard CHAT2 slack (cf. tests/test_poisson.py)
    p, errs = observed_order("unb", layout, GreenKind.CHAT2, ns=(16, 24, 32))
    assert p > 1.55, (p, errs)
    assert errs[0] > errs[1] > errs[2], errs


@pytest.mark.slow
@pytest.mark.parametrize("layout", LAYOUTS)
def test_unbounded_hej4_order(layout):
    p, errs = observed_order("unb", layout, GreenKind.HEJ4, ns=(32, 48, 64))
    assert p > 3.15, (p, errs)        # measured 3.42 / 3.37, design 4


@pytest.mark.slow
@pytest.mark.parametrize("layout", LAYOUTS)
def test_unbounded_hej6_order(layout):
    p, errs = observed_order("unb", layout, GreenKind.HEJ6, ns=(48, 64, 96))
    assert p > 5.2, (p, errs)         # measured 5.54 / 5.49, design 6


# ---------------------------------------------------------------------------
# semi-unbounded (paper Fig 7): even and odd bounded ends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", ["semi-even", "semi-odd"])
@pytest.mark.parametrize("layout", LAYOUTS)
def test_semi_unbounded_chat2_order2(case, layout):
    p, errs = observed_order(case, layout, GreenKind.CHAT2, ns=(16, 24, 32))
    assert p > 1.55, (p, errs)
    assert errs[0] > errs[1] > errs[2], errs


@pytest.mark.slow
@pytest.mark.parametrize("layout", LAYOUTS)
def test_semi_unbounded_hej4_order(layout):
    p, errs = observed_order("semi-even", layout, GreenKind.HEJ4,
                             ns=(32, 48, 64))
    assert p > 3.15, (p, errs)        # measured 3.42 / 3.37


@pytest.mark.slow
@pytest.mark.parametrize("layout", LAYOUTS)
def test_semi_unbounded_hej6_order(layout):
    p, errs = observed_order("semi-even", layout, GreenKind.HEJ6,
                             ns=(48, 64, 96))
    assert p > 5.2, (p, errs)         # measured 5.53 / 5.49


# ---------------------------------------------------------------------------
# fully periodic (spectral BCs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", LAYOUTS)
def test_periodic_chat2_spectral(layout):
    """CHAT2 is the exact inverse symbol on periodic boxes: the error is
    pure rhs-sampling aliasing, decaying super-algebraically (>> order 2)."""
    p, errs = observed_order("per", layout, GreenKind.CHAT2, ns=(8, 12, 16))
    assert p > 2.0, (p, errs)
    assert errs[-1] < 1e-6, errs


@pytest.mark.parametrize("green,thresh", [
    (GreenKind.HEJ4, 3.3),            # measured 3.75, design 4
    (GreenKind.HEJ6, 5.2),            # measured 5.63, design 6
])
def test_periodic_hej_orders(green, thresh):
    """Regularized kernels on a periodic box keep their design order."""
    p, errs = observed_order("per-wide", DataLayout.NODE, green,
                             ns=(24, 32, 48))
    assert p > thresh, (p, errs)


# ---------------------------------------------------------------------------
# batched validation: the multi-RHS pipeline reproduces the analytical
# solution for every rhs in the batch (ties the tentpole to the paper net)
# ---------------------------------------------------------------------------

def test_batched_solve_matches_analytical():
    n, layout = 24, DataLayout.NODE
    fn, bcs = CASES["unb"]
    rhs, sol = fn(n, layout)
    s = get_solver((n, n, n), L, bcs, layout=layout,
                   green_kind=GreenKind.CHAT2)
    scales = np.array([1.0, -2.0, 0.5])
    fb = np.stack([a * rhs for a in scales])
    ub = np.asarray(s.solve(fb.astype(np.float64)))
    ref_err = float(np.max(np.abs(ub[0] - sol)))
    for a, u in zip(scales, ub):
        assert np.max(np.abs(u - a * sol)) <= abs(a) * ref_err * (1 + 1e-10)
